//! Span tracing into per-thread lock-free ring buffers.
//!
//! Every instrumented thread owns one [`SpanRing`]: a fixed-capacity
//! ring of seqlock slots whose payload words are plain `AtomicU64`s, so
//! the whole thing is safe code — a reader that races a writer observes
//! a torn sequence number and simply discards the slot. The owning
//! thread is the only writer (one atomic store per word, no CAS loops,
//! no locks), which keeps the record path at ~10 relaxed stores; when
//! the ring is full the oldest event is overwritten and counted in
//! `dropped`.
//!
//! Rings register themselves in a process-wide list on first use;
//! [`collect`] snapshots every ring, drops torn slots, and merges the
//! rest into one start-time-ordered event list. Collection normally
//! happens after the instrumented work has quiesced (end of a sweep),
//! but racing a live writer is merely lossy, never unsafe.
//!
//! Span names are `&'static str` interned to small ids so events stay
//! plain words. The well-known taxonomy lives in [`names`]; unknown
//! names fall back to a mutex-guarded side table (cold path only).

use std::sync::atomic::{fence, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{now_ns, trace_on};

/// The well-known span taxonomy (see `docs/observability.md`).
pub mod names {
    /// Application packing (flow stage).
    pub const PACK: &str = "pnr.pack";
    /// Analytic global placement — one span per solver call; `arg0` =
    /// batch size (1 for the scalar path).
    pub const GLOBAL_PLACE: &str = "pnr.global_place";
    /// Placement legalization (flow stage).
    pub const LEGALIZE: &str = "pnr.legalize";
    /// Simulated-annealing detailed placement; `arg0` = moves/node,
    /// `arg1` = 1 when it is a warm-start refinement pass.
    pub const SA: &str = "pnr.sa";
    /// PathFinder routing; `arg0` = nets routed, `arg1` = 1 on the
    /// warm seeded path.
    pub const ROUTE: &str = "pnr.route";
    /// Static timing analysis (flow stage).
    pub const STA: &str = "pnr.sta";
    /// Elastic (ready-valid) simulation of a routed point.
    pub const SIM: &str = "pnr.sim";

    /// One DSE job end-to-end (prepare → place → finish); `arg0` = job
    /// index, `arg1` = 1 when the job warm-started from a donor.
    pub const JOB: &str = "dse.job";
    /// Draining one per-config job group through a single batched
    /// placement solve; `arg0` = group size.
    pub const PLACE_BATCH: &str = "dse.place_batch";
    /// Resolving a `(config, app, seed)` key against the artifact store.
    pub const ARTIFACT_RESOLVE: &str = "dse.artifact.resolve";
    /// Instant: a warm-start donor was picked; `arg0` = axis distance.
    pub const DONOR_PICK: &str = "dse.donor_pick";
    /// Instant: result-cache hit for a sweep job.
    pub const CACHE_HIT: &str = "dse.cache.hit";
    /// Instant: result-cache miss (the job goes to the cold executor).
    pub const CACHE_MISS: &str = "dse.cache.miss";

    /// One daemon request end-to-end; `arg0` = request id.
    pub const REQUEST: &str = "svc.request";
    /// Instant: a daemon `dse` job was served from the shared cache.
    pub const DSE_HIT: &str = "svc.dse.hit";
    /// Instant: a daemon `dse` job joined another request's in-flight
    /// computation (coalescing).
    pub const DSE_JOIN: &str = "svc.dse.join";
    /// Instant: a daemon `dse` job was claimed for cold execution.
    pub const DSE_CLAIM: &str = "svc.dse.claim";

    // Per-search-core routing spans (one per `RouterParams::search_core`
    // variant, nested inside [`ROUTE`]); `arg0` = frontier expansions.
    // Appended after the PR 7 taxonomy so existing interned ids are
    // unchanged (ids index `WELL_KNOWN`).
    /// Routing with the default binary-heap frontier.
    pub const ROUTE_BINARY_HEAP: &str = "pnr.route.binary-heap";
    /// Routing with the bucketed frontier (PR 6's `bucket_queue`).
    pub const ROUTE_BUCKET: &str = "pnr.route.bucket";
    /// Routing with the radix (IEEE-bits bucketed) frontier.
    pub const ROUTE_RADIX: &str = "pnr.route.radix";
    /// Routing with the full-strength admissible A* heuristic.
    pub const ROUTE_ASTAR: &str = "pnr.route.astar";
    /// Routing with the bidirectional Dijkstra core.
    pub const ROUTE_BIDIR: &str = "pnr.route.bidir";

    // Autotuner spans (one family per `canal tune` run). Appended after
    // the PR 8 taxonomy so existing interned ids are unchanged (ids
    // index `WELL_KNOWN`).
    /// One `canal tune` search end-to-end; `arg0` = cross-product size.
    pub const DSE_TUNE: &str = "dse.tune";
    /// Cheap-model pre-pruning pass; `arg0` = candidates in, `arg1` =
    /// candidates discarded.
    pub const TUNE_PRUNE: &str = "dse.tune.prune";
    /// One successive-halving round; `arg0` = round index, `arg1` =
    /// survivors entering the round.
    pub const TUNE_ROUND: &str = "dse.tune.round";

    /// Every name above, in id order (ids index this table).
    pub const WELL_KNOWN: &[&str] = &[
        PACK,
        GLOBAL_PLACE,
        LEGALIZE,
        SA,
        ROUTE,
        STA,
        SIM,
        JOB,
        PLACE_BATCH,
        ARTIFACT_RESOLVE,
        DONOR_PICK,
        CACHE_HIT,
        CACHE_MISS,
        REQUEST,
        DSE_HIT,
        DSE_JOIN,
        DSE_CLAIM,
        ROUTE_BINARY_HEAP,
        ROUTE_BUCKET,
        ROUTE_RADIX,
        ROUTE_ASTAR,
        ROUTE_BIDIR,
        DSE_TUNE,
        TUNE_PRUNE,
        TUNE_ROUND,
    ];
}

/// Default per-thread ring capacity (events). ~4k events × 48 B ≈ 200 KB
/// per instrumented thread, allocated lazily on the thread's first span.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Complete span vs. zero-duration instant event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Span,
    Instant,
}

/// One collected event, decoded from a ring slot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    pub name: &'static str,
    pub kind: SpanKind,
    /// Track id — the owning ring's worker number (registration order).
    pub worker: u32,
    /// Nanoseconds since the obs epoch ([`now_ns`]).
    pub start_ns: u64,
    pub dur_ns: u64,
    pub arg0: u64,
    pub arg1: u64,
}

const KIND_SPAN: u64 = 0;
const KIND_INSTANT: u64 = 1;

fn pack_meta(name_id: u32, kind: u64) -> u64 {
    (name_id as u64) | (kind << 32)
}

// --- name interning ------------------------------------------------------

fn extra_names() -> &'static Mutex<Vec<&'static str>> {
    static EXTRA: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    EXTRA.get_or_init(|| Mutex::new(Vec::new()))
}

fn intern(name: &'static str) -> u32 {
    if let Some(i) = names::WELL_KNOWN.iter().position(|n| *n == name) {
        return i as u32;
    }
    let mut extra = extra_names().lock().unwrap_or_else(|p| p.into_inner());
    if let Some(i) = extra.iter().position(|n| *n == name) {
        return (names::WELL_KNOWN.len() + i) as u32;
    }
    extra.push(name);
    (names::WELL_KNOWN.len() + extra.len() - 1) as u32
}

fn name_of(id: u32) -> &'static str {
    let id = id as usize;
    if id < names::WELL_KNOWN.len() {
        return names::WELL_KNOWN[id];
    }
    let extra = extra_names().lock().unwrap_or_else(|p| p.into_inner());
    extra.get(id - names::WELL_KNOWN.len()).copied().unwrap_or("?")
}

// --- the ring ------------------------------------------------------------

const SLOT_WORDS: usize = 5;
const W_META: usize = 0;
const W_START: usize = 1;
const W_DUR: usize = 2;
const W_ARG0: usize = 3;
const W_ARG1: usize = 4;

/// One seqlock slot: `seq` is `2·h + 1` while event `h` is being
/// written and `2·(h + 1)` once it is stable, so a reader can both
/// detect torn reads (odd or changed `seq`) and recover the event's
/// global push index (`seq / 2 − 1`) for merge ordering.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; SLOT_WORDS],
}

impl Slot {
    fn new() -> Slot {
        Slot { seq: AtomicU64::new(0), words: Default::default() }
    }
}

/// A single thread's span ring (see the module docs for the protocol).
pub struct SpanRing {
    worker: u32,
    label: Mutex<Option<String>>,
    slots: Box<[Slot]>,
    /// Events ever pushed (monotonic; `min(head, capacity)` live).
    head: AtomicU64,
    /// Events overwritten before collection (drop-oldest accounting).
    dropped: AtomicU64,
}

impl SpanRing {
    /// `capacity` is rounded up to a power of two (min 2).
    pub fn with_capacity(worker: u32, capacity: usize) -> SpanRing {
        let cap = capacity.max(2).next_power_of_two();
        SpanRing {
            worker,
            label: Mutex::new(None),
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn worker(&self) -> u32 {
        self.worker
    }

    /// Record one event. Intended for the owning thread only; a second
    /// concurrent writer is safe (no UB) but may interleave slots.
    pub fn push(&self, meta: u64, start_ns: u64, dur_ns: u64, arg0: u64, arg1: u64) {
        let h = self.head.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        if h >= cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        let slot = &self.slots[(h % cap) as usize];
        slot.seq.store(2 * h + 1, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        slot.words[W_META].store(meta, Ordering::Relaxed);
        slot.words[W_START].store(start_ns, Ordering::Relaxed);
        slot.words[W_DUR].store(dur_ns, Ordering::Relaxed);
        slot.words[W_ARG0].store(arg0, Ordering::Relaxed);
        slot.words[W_ARG1].store(arg1, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        slot.seq.store(2 * (h + 1), Ordering::SeqCst);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Total events ever pushed.
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Events lost to ring overwrite so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Snapshot the stable slots, oldest first. Slots torn by a racing
    /// writer are skipped.
    pub fn drain_events(&self) -> Vec<SpanEvent> {
        let mut out: Vec<(u64, SpanEvent)> = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::SeqCst);
            if s1 == 0 || s1 % 2 == 1 {
                continue; // never written, or mid-write
            }
            fence(Ordering::SeqCst);
            let meta = slot.words[W_META].load(Ordering::Relaxed);
            let start_ns = slot.words[W_START].load(Ordering::Relaxed);
            let dur_ns = slot.words[W_DUR].load(Ordering::Relaxed);
            let arg0 = slot.words[W_ARG0].load(Ordering::Relaxed);
            let arg1 = slot.words[W_ARG1].load(Ordering::Relaxed);
            fence(Ordering::SeqCst);
            if slot.seq.load(Ordering::SeqCst) != s1 {
                continue; // torn by a concurrent push
            }
            let kind =
                if meta >> 32 == KIND_INSTANT { SpanKind::Instant } else { SpanKind::Span };
            let ev = SpanEvent {
                name: name_of((meta & 0xffff_ffff) as u32),
                kind,
                worker: self.worker,
                start_ns,
                dur_ns,
                arg0,
                arg1,
            };
            out.push((s1 / 2 - 1, ev));
        }
        out.sort_by_key(|(idx, _)| *idx);
        out.into_iter().map(|(_, ev)| ev).collect()
    }

    fn set_label(&self, label: &str) {
        *self.label.lock().unwrap_or_else(|p| p.into_inner()) = Some(label.to_string());
    }

    /// The track label, defaulting to `worker-<n>`.
    pub fn label(&self) -> String {
        self.label
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
            .unwrap_or_else(|| format!("worker-{}", self.worker))
    }
}

// --- registry + thread-locals --------------------------------------------

fn rings() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_WORKER: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static LOCAL_RING: std::cell::OnceCell<Arc<SpanRing>> = const { std::cell::OnceCell::new() };
}

fn local_ring() -> Arc<SpanRing> {
    LOCAL_RING.with(|cell| {
        Arc::clone(cell.get_or_init(|| {
            let worker = NEXT_WORKER.fetch_add(1, Ordering::Relaxed);
            let ring = Arc::new(SpanRing::with_capacity(worker, DEFAULT_RING_CAPACITY));
            rings().lock().unwrap_or_else(|p| p.into_inner()).push(Arc::clone(&ring));
            ring
        }))
    })
}

/// Name the current thread's track in the exported trace (e.g.
/// `dse-worker-3`). No-op unless tracing is enabled.
pub fn label_thread(label: &str) {
    if trace_on() {
        local_ring().set_label(label);
    }
}

/// Merge every ring's stable events into one list ordered by
/// `(start_ns, worker)`.
pub fn collect() -> Vec<SpanEvent> {
    let rings: Vec<Arc<SpanRing>> =
        rings().lock().unwrap_or_else(|p| p.into_inner()).clone();
    let mut out = Vec::new();
    for ring in &rings {
        out.extend(ring.drain_events());
    }
    out.sort_by_key(|ev| (ev.start_ns, ev.worker, ev.dur_ns));
    out
}

/// Per-track labels for every registered ring, keyed by worker id.
pub fn track_labels() -> Vec<(u32, String)> {
    let rings: Vec<Arc<SpanRing>> =
        rings().lock().unwrap_or_else(|p| p.into_inner()).clone();
    let mut out: Vec<(u32, String)> =
        rings.iter().map(|r| (r.worker(), r.label())).collect();
    out.sort_by_key(|(w, _)| *w);
    out
}

/// `(pushed, dropped)` totals across every registered ring.
pub fn totals() -> (u64, u64) {
    let rings: Vec<Arc<SpanRing>> =
        rings().lock().unwrap_or_else(|p| p.into_inner()).clone();
    rings
        .iter()
        .fold((0, 0), |(p, d), r| (p + r.pushed(), d + r.dropped()))
}

// --- guards --------------------------------------------------------------

/// RAII span: records `(name, start, duration, args)` into the calling
/// thread's ring on drop. Inert (a few moves, no stores) when tracing
/// is off at creation time.
pub struct SpanGuard {
    meta: u64,
    start_ns: u64,
    arg0: u64,
    arg1: u64,
    live: bool,
}

impl SpanGuard {
    /// Attach both payload args (meaning is per-name; see [`names`]).
    pub fn args(&mut self, arg0: u64, arg1: u64) {
        self.arg0 = arg0;
        self.arg1 = arg1;
    }

    pub fn arg0(&mut self, arg0: u64) {
        self.arg0 = arg0;
    }

    pub fn arg1(&mut self, arg1: u64) {
        self.arg1 = arg1;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            let dur = now_ns().saturating_sub(self.start_ns);
            local_ring().push(self.meta, self.start_ns, dur, self.arg0, self.arg1);
        }
    }
}

/// Open a span; it records when the guard drops. One relaxed load when
/// tracing is disabled.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !trace_on() {
        return SpanGuard { meta: 0, start_ns: 0, arg0: 0, arg1: 0, live: false };
    }
    SpanGuard {
        meta: pack_meta(intern(name), KIND_SPAN),
        start_ns: now_ns(),
        arg0: 0,
        arg1: 0,
        live: true,
    }
}

/// Record an instant event (zero duration).
#[inline]
pub fn event(name: &'static str, arg0: u64, arg1: u64) {
    if !trace_on() {
        return;
    }
    let t = now_ns();
    local_ring().push(pack_meta(intern(name), KIND_INSTANT), t, 0, arg0, arg1);
}

/// A flow-stage guard: a [`span`] that additionally bumps the stage's
/// `<name>.count` counter and `<name>.ns` duration histogram in the
/// metrics registry on drop. The single-load fast path applies: with
/// the whole gate off this is inert.
pub struct StageGuard {
    name: &'static str,
    inner: SpanGuard,
    metrics: bool,
    start_ns: u64,
}

impl StageGuard {
    pub fn args(&mut self, arg0: u64, arg1: u64) {
        self.inner.args(arg0, arg1);
    }
}

impl Drop for StageGuard {
    fn drop(&mut self) {
        if self.metrics {
            let dur = now_ns().saturating_sub(self.start_ns);
            super::metrics::counter(&format!("{}.count", self.name)).inc();
            super::metrics::histogram(&format!("{}.ns", self.name)).record(dur);
        }
        // `inner` drops after this body and records the span itself.
    }
}

/// Open a flow-stage guard (span + counter + duration histogram).
#[inline]
pub fn stage(name: &'static str) -> StageGuard {
    if !super::enabled() {
        return StageGuard {
            name,
            inner: SpanGuard { meta: 0, start_ns: 0, arg0: 0, arg1: 0, live: false },
            metrics: false,
            start_ns: 0,
        };
    }
    StageGuard { name, inner: span(name), metrics: super::metrics_on(), start_ns: now_ns() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let ring = SpanRing::with_capacity(900, 8);
        for i in 0..11u64 {
            ring.push(pack_meta(intern(names::ROUTE), KIND_SPAN), 100 + i, 5, i, 0);
        }
        assert_eq!(ring.pushed(), 11);
        assert_eq!(ring.dropped(), 3, "3 events past capacity were overwritten");
        let evs = ring.drain_events();
        assert_eq!(evs.len(), 8);
        // The oldest three (arg0 = 0, 1, 2) are gone; order is push order.
        assert_eq!(evs.iter().map(|e| e.arg0).collect::<Vec<_>>(), (3..11).collect::<Vec<_>>());
        assert!(evs.iter().all(|e| e.name == names::ROUTE));
    }

    #[test]
    fn ring_decodes_kind_and_args() {
        let ring = SpanRing::with_capacity(901, 4);
        ring.push(pack_meta(intern(names::SA), KIND_SPAN), 7, 3, 12, 1);
        ring.push(pack_meta(intern(names::CACHE_HIT), KIND_INSTANT), 9, 0, 0, 0);
        let evs = ring.drain_events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, names::SA);
        assert_eq!(evs[0].kind, SpanKind::Span);
        assert_eq!((evs[0].start_ns, evs[0].dur_ns, evs[0].arg0, evs[0].arg1), (7, 3, 12, 1));
        assert_eq!(evs[1].kind, SpanKind::Instant);
    }

    #[test]
    fn interning_round_trips_well_known_and_extra() {
        for (i, n) in names::WELL_KNOWN.iter().enumerate() {
            assert_eq!(intern(n), i as u32);
            assert_eq!(name_of(i as u32), *n);
        }
        let id = intern("test.custom.span");
        assert_eq!(name_of(id), "test.custom.span");
        assert_eq!(intern("test.custom.span"), id, "interning is stable");
    }

    #[test]
    fn disabled_guards_record_nothing() {
        // The gate is off by default in unit tests unless another test
        // in this process enabled it; force it off for the check.
        let _gate = crate::obs::test_gate_lock();
        let prev = crate::obs::ObsOptions::current();
        crate::obs::ObsOptions::disabled().apply();
        let before = totals();
        {
            let mut g = span(names::PACK);
            g.args(1, 2);
            event(names::CACHE_HIT, 0, 0);
            let _s = stage(names::ROUTE);
        }
        assert_eq!(totals(), before, "disabled guards must not touch any ring");
        prev.apply();
    }

    #[test]
    fn collect_merges_threads_in_time_order() {
        // Spans recorded on freshly spawned threads land in separate
        // rings; filter collect() output down to this test's unique arg
        // marker so concurrently-running tests can't interfere.
        let marker = 0xC0FFEE_u64;
        let _gate = crate::obs::test_gate_lock();
        let prev = crate::obs::ObsOptions::current();
        crate::obs::ObsOptions { metrics: prev.metrics, trace: true }.apply();
        let handles: Vec<_> = (0..2)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut g = span(names::SIM);
                    g.args(marker, i);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        prev.apply();
        let mine: Vec<SpanEvent> =
            collect().into_iter().filter(|e| e.arg0 == marker).collect();
        assert_eq!(mine.len(), 2);
        assert!(mine[0].start_ns <= mine[1].start_ns, "merged events are time-ordered");
        assert_ne!(mine[0].worker, mine[1].worker, "each thread gets its own track");
    }
}
