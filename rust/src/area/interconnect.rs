//! Area accounting over the interconnect IR.
//!
//! Walks the routing graphs and prices every lowered component (SB muxes,
//! CB muxes, pipeline registers, config storage, and — for the ready-valid
//! backend — FIFOs, valid paths and ready-join logic), per tile and per
//! structure. Feeds Figs. 8, 10 and 13.

use std::collections::BTreeMap;

use crate::ir::{Interconnect, NodeKind, SbIo};

use super::model::AreaModel;

/// Which hardware backend the area is priced for (§3.3 / Fig. 8).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FabricMode {
    /// Fully static interconnect (baseline bar of Fig. 8).
    Static,
    /// Ready-valid with a full depth-`fifo_depth` FIFO at every register.
    ReadyValidFullFifo { fifo_depth: usize },
    /// Ready-valid with the split-FIFO optimization (Fig. 6).
    ReadyValidSplitFifo,
}

impl FabricMode {
    pub fn name(self) -> &'static str {
        match self {
            FabricMode::Static => "static",
            FabricMode::ReadyValidFullFifo { .. } => "rv-full-fifo",
            FabricMode::ReadyValidSplitFifo => "rv-split-fifo",
        }
    }
}

/// Area of one tile, broken down by structure (µm²).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TileArea {
    pub sb_um2: f64,
    pub cb_um2: f64,
    pub config_um2: f64,
}

impl TileArea {
    pub fn total(&self) -> f64 {
        self.sb_um2 + self.cb_um2 + self.config_um2
    }
}

/// Area report for an interconnect.
#[derive(Clone, Debug, Default)]
pub struct AreaReport {
    pub per_tile: BTreeMap<(u16, u16), TileArea>,
}

impl AreaReport {
    pub fn total_um2(&self) -> f64 {
        self.per_tile.values().map(TileArea::total).sum()
    }

    pub fn total_sb_um2(&self) -> f64 {
        self.per_tile.values().map(|t| t.sb_um2).sum()
    }

    pub fn total_cb_um2(&self) -> f64 {
        self.per_tile.values().map(|t| t.cb_um2).sum()
    }

    pub fn total_config_um2(&self) -> f64 {
        self.per_tile.values().map(|t| t.config_um2).sum()
    }

    /// Area of a representative *interior* tile (margin tiles have smaller
    /// muxes); this is what the paper's per-SB/per-CB bars report.
    pub fn interior_tile(&self, ic: &Interconnect) -> TileArea {
        let (x, y) = (ic.width / 2, ic.height / 2);
        self.per_tile[&(x, y)]
    }
}

/// Price the whole interconnect under a fabric mode.
pub fn area_of(ic: &Interconnect, model: &AreaModel, mode: FabricMode) -> AreaReport {
    let mut report = AreaReport::default();
    for tile in &ic.tiles {
        report.per_tile.insert((tile.x, tile.y), TileArea::default());
    }

    let rv = !matches!(mode, FabricMode::Static);

    for g in ic.graphs.values() {
        for (id, node) in g.iter() {
            let entry = report.per_tile.get_mut(&(node.x, node.y)).expect("tile exists");
            let fan_in = g.fan_in(id).len();
            match &node.kind {
                // SB output = data mux + its config; RV adds the valid
                // mirror and ready-join logic.
                NodeKind::SwitchBox { io: SbIo::Out, .. } => {
                    entry.sb_um2 += model.to_um2(model.mux_ge(fan_in, node.width));
                    entry.config_um2 += model.to_um2(model.mux_config_ge(fan_in));
                    if rv {
                        entry.sb_um2 += model.to_um2(model.valid_path_ge(fan_in));
                        entry.sb_um2 += model.to_um2(model.ready_join_ge(fan_in));
                    }
                }
                NodeKind::SwitchBox { io: SbIo::In, .. } => {}
                // Input port = CB mux + config (+ RV mirrors).
                NodeKind::Port { input: true, .. } => {
                    entry.cb_um2 += model.to_um2(model.mux_ge(fan_in, node.width));
                    entry.config_um2 += model.to_um2(model.mux_config_ge(fan_in));
                    if rv {
                        entry.cb_um2 += model.to_um2(model.valid_path_ge(fan_in));
                        entry.cb_um2 += model.to_um2(model.ready_join_ge(fan_in));
                    }
                }
                NodeKind::Port { input: false, .. } => {}
                // Pipeline register; in RV modes it becomes (part of) a
                // FIFO.
                NodeKind::Register { .. } => {
                    entry.sb_um2 += model.to_um2(model.register_ge(node.width));
                    match mode {
                        FabricMode::Static => {}
                        FabricMode::ReadyValidFullFifo { fifo_depth } => {
                            entry.sb_um2 +=
                                model.to_um2(model.fifo_extra_ge(fifo_depth, node.width));
                        }
                        FabricMode::ReadyValidSplitFifo => {
                            entry.sb_um2 += model.to_um2(model.split_fifo_extra_ge());
                        }
                    }
                }
                // Register bypass mux (2:1) + 1 config bit.
                NodeKind::RegMux { .. } => {
                    entry.sb_um2 += model.to_um2(model.mux_ge(fan_in, node.width));
                    entry.config_um2 += model.to_um2(model.mux_config_ge(fan_in));
                }
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};

    fn baseline_ic(tracks: u16) -> Interconnect {
        let cfg = InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: tracks,
            mem_column_period: 0,
            ..Default::default()
        };
        create_uniform_interconnect(&cfg)
    }

    #[test]
    fn fig8_overheads_in_paper_range() {
        // Paper §4.1: depth-2 FIFOs add 54% SB area over the static
        // baseline; the split FIFO only 32%. We require the model to land
        // near those ratios (the constants are calibrated for this).
        let ic = baseline_ic(5);
        let m = AreaModel::default();
        let base = area_of(&ic, &m, FabricMode::Static).interior_tile(&ic).sb_um2;
        let full = area_of(&ic, &m, FabricMode::ReadyValidFullFifo { fifo_depth: 2 })
            .interior_tile(&ic)
            .sb_um2;
        let split =
            area_of(&ic, &m, FabricMode::ReadyValidSplitFifo).interior_tile(&ic).sb_um2;
        let full_ovh = full / base - 1.0;
        let split_ovh = split / base - 1.0;
        assert!((0.44..0.64).contains(&full_ovh), "full-FIFO overhead {full_ovh:.3}");
        assert!((0.22..0.42).contains(&split_ovh), "split-FIFO overhead {split_ovh:.3}");
        assert!(split_ovh < full_ovh);
    }

    #[test]
    fn fig10_area_scales_with_tracks() {
        let m = AreaModel::default();
        let mut prev_sb = 0.0;
        let mut prev_cb = 0.0;
        for tracks in [2u16, 4, 6, 8] {
            let ic = baseline_ic(tracks);
            let t = area_of(&ic, &m, FabricMode::Static).interior_tile(&ic);
            assert!(t.sb_um2 > prev_sb, "SB area must grow with tracks");
            assert!(t.cb_um2 > prev_cb, "CB area must grow with tracks");
            prev_sb = t.sb_um2;
            prev_cb = t.cb_um2;
        }
    }

    #[test]
    fn margin_tiles_cheaper_than_interior() {
        let ic = baseline_ic(5);
        let m = AreaModel::default();
        let r = area_of(&ic, &m, FabricMode::Static);
        let corner = r.per_tile[&(0, 0)];
        let interior = r.interior_tile(&ic);
        assert!(corner.total() <= interior.total());
    }

    #[test]
    fn totals_are_sums_of_tiles() {
        let ic = baseline_ic(3);
        let m = AreaModel::default();
        let r = area_of(&ic, &m, FabricMode::Static);
        let sum: f64 = r.per_tile.values().map(TileArea::total).sum();
        assert!((r.total_um2() - sum).abs() < 1e-9);
        assert!(r.total_um2() > 0.0);
    }
}
