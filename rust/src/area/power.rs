//! Interconnect energy model.
//!
//! The paper motivates interconnect DSE with the observation that the
//! reconfigurable interconnect is "over 50% of the CGRA area and 25% of
//! the CGRA energy" [Vasilyev et al.]. This module prices dynamic energy
//! per routed application: every net sink path charges the muxes, wires
//! and registers it traverses per token, plus per-cycle clock load on
//! configured registers; PE/MEM compute energy uses per-op constants so
//! the interconnect *share* can be reported.

use crate::ir::{Interconnect, NodeKind, SbIo};
use crate::pnr::app::AppOp;
use crate::pnr::{PackedApp, RoutingResult};

/// Energy constants (fJ at nominal voltage, 12nm-representative; only
/// relative magnitudes matter for the share-of-energy experiments).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// Switching one mux (per bit).
    pub mux_fj_per_bit: f64,
    /// Driving one inter-tile track hop (per bit).
    pub wire_fj_per_bit: f64,
    /// Register clocking per cycle (per bit, includes clock tree share).
    pub reg_clk_fj_per_bit: f64,
    /// PE ALU op.
    pub alu_op_fj: f64,
    /// Memory access.
    pub mem_access_fj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            mux_fj_per_bit: 1.4,
            wire_fj_per_bit: 4.2,
            reg_clk_fj_per_bit: 1.1,
            // 16-bit multiply-class PE op and SRAM access energies in a
            // 12nm-class node; calibrated so the interconnect share of
            // stencil apps lands near the ~25% the paper cites.
            alu_op_fj: 1200.0,
            mem_access_fj: 2600.0,
        }
    }
}

/// Energy report for one routed application (pJ for a whole workload).
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyReport {
    pub interconnect_pj: f64,
    pub compute_pj: f64,
    pub tokens: usize,
}

impl EnergyReport {
    pub fn total_pj(&self) -> f64 {
        self.interconnect_pj + self.compute_pj
    }

    /// The paper's headline ratio: interconnect share of total energy.
    pub fn interconnect_share(&self) -> f64 {
        self.interconnect_pj / self.total_pj().max(1e-12)
    }
}

/// Estimate energy for `tokens` streamed elements through a routed app.
pub fn energy_of(
    ic: &Interconnect,
    packed: &PackedApp,
    routing: &RoutingResult,
    bit_width: u8,
    model: &EnergyModel,
    tokens: usize,
) -> EnergyReport {
    let g = ic.graph(bit_width);
    let bits = bit_width as f64;
    let mut interconnect_fj_per_token = 0.0;

    for tree in &routing.trees {
        for path in &tree.sink_paths {
            for (i, &n) in path.iter().enumerate() {
                match &g.node(n).kind {
                    // Every traversed mux switches once per token.
                    NodeKind::SwitchBox { io: SbIo::Out, .. }
                    | NodeKind::Port { input: true, .. }
                    | NodeKind::RegMux { .. } => {
                        interconnect_fj_per_token += model.mux_fj_per_bit * bits;
                    }
                    NodeKind::Register { .. } => {
                        interconnect_fj_per_token += model.reg_clk_fj_per_bit * bits;
                    }
                    _ => {}
                }
                if i + 1 < path.len() && g.wire_delay(n, path[i + 1]) > 0 {
                    interconnect_fj_per_token += model.wire_fj_per_bit * bits;
                }
            }
        }
    }

    let mut compute_fj_per_token = 0.0;
    for (_, n) in packed.app.iter() {
        compute_fj_per_token += match n.op {
            AppOp::Alu(_) => model.alu_op_fj,
            AppOp::Mem(_) => model.mem_access_fj,
            AppOp::Reg => model.reg_clk_fj_per_bit * bits,
            AppOp::Const(_) => 0.0,
        };
    }
    // Packed input registers clock every cycle too.
    compute_fj_per_token +=
        packed.packed_regs.len() as f64 * model.reg_clk_fj_per_bit * bits;

    EnergyReport {
        interconnect_pj: interconnect_fj_per_token * tokens as f64 / 1000.0,
        compute_pj: compute_fj_per_token * tokens as f64 / 1000.0,
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::pnr::{run_flow, FlowParams, SaParams};

    fn routed(app_name: &str) -> (Interconnect, PackedApp, RoutingResult) {
        let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
        let app = apps::suite().into_iter().find(|a| a.name == app_name).unwrap();
        let params = FlowParams {
            sa: SaParams { moves_per_node: 6, ..Default::default() },
            ..Default::default()
        };
        let r = run_flow(&ic, &app, &params).unwrap();
        (ic, r.packed, r.routing)
    }

    #[test]
    fn energy_scales_linearly_with_tokens() {
        let (ic, packed, routing) = routed("gaussian");
        let m = EnergyModel::default();
        let e1 = energy_of(&ic, &packed, &routing, 16, &m, 1000);
        let e4 = energy_of(&ic, &packed, &routing, 16, &m, 4000);
        assert!((e4.total_pj() / e1.total_pj() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn interconnect_share_in_plausible_band() {
        // The Vasilyev/paper motivation: interconnect ≈ 25% of energy.
        // Our model should land in a broad band around that for stencil
        // apps (10%..45%) — it is a calibration sanity check, not a claim.
        let (ic, packed, routing) = routed("harris");
        let e = energy_of(&ic, &packed, &routing, 16, &EnergyModel::default(), 4096);
        let share = e.interconnect_share();
        assert!((0.08..0.5).contains(&share), "share {share}");
    }

    #[test]
    fn longer_routes_cost_more_energy() {
        let (ic, packed, routing) = routed("pointwise");
        let m = EnergyModel::default();
        let e = energy_of(&ic, &packed, &routing, 16, &m, 1024);
        // Doubling wire energy must increase interconnect energy.
        let m2 = EnergyModel { wire_fj_per_bit: m.wire_fj_per_bit * 2.0, ..m };
        let e2 = energy_of(&ic, &packed, &routing, 16, &m2, 1024);
        assert!(e2.interconnect_pj > e.interconnect_pj);
        assert_eq!(e2.compute_pj, e.compute_pj);
    }
}
