//! Gate-level area model.
//!
//! The paper reports areas from Global Foundries 12 nm synthesis. We have
//! no PDK, so we substitute a component-level model in *gate equivalents*
//! (GE = one NAND2), converted to µm² with a GF12-representative factor.
//! Every paper claim this model feeds is *relative* (overhead percentages
//! in Fig. 8, scaling trends in Figs. 10/13), which gate-count models
//! capture faithfully; see DESIGN.md §3.

/// Technology/area constants. Defaults approximate a 12 nm standard-cell
/// library; `calibration` tests pin the Fig. 8 ratios.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// µm² per gate equivalent.
    pub um2_per_ge: f64,
    /// One bit of a 2:1 mux (the unit of an AOI mux tree).
    pub mux2_ge: f64,
    /// One flip-flop bit (config or datapath).
    pub flop_ge: f64,
    /// One-hot decoder cost per decoded output bit (AOI mux select
    /// pre-decode — the paper reuses these signals for ready joining).
    pub decoder_ge_per_out: f64,
    /// FIFO control per register entry converted to FIFO duty: pointer
    /// bits, full/empty comparators, enqueue/dequeue handshake.
    pub fifo_ctrl_ge_per_entry: f64,
    /// Extra control for the *split* FIFO: cross-tile handshake plus the
    /// chained enable logic of Fig. 6 (no second data register!).
    pub split_fifo_ctrl_ge: f64,
    /// Ready-join logic per mux input: OR of inverted one-hot with the
    /// per-direction ready, plus its share of the final AND tree (Fig. 5).
    pub ready_join_ge_per_input: f64,
    /// Per-track valid-signal routing overhead (1-bit mux mirror of the
    /// data mux).
    pub valid_path_ge_per_input: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            um2_per_ge: 0.121,
            mux2_ge: 1.79,
            flop_ge: 4.49,
            decoder_ge_per_out: 1.2,
            // Calibrated so the Fig. 8 experiment reproduces the paper's
            // overheads on the §4.1 baseline (five 16-bit tracks, 4-in /
            // 2-out PEs): +54% for depth-2 FIFOs, +32% for split FIFOs.
            // The split-FIFO control is richer than a single in-tile
            // entry's (cross-tile handshake, per-register position
            // configuration — §3.3), which is why it exceeds
            // 2x `fifo_ctrl_ge_per_entry`.
            fifo_ctrl_ge_per_entry: 13.0,
            split_fifo_ctrl_ge: 48.0,
            ready_join_ge_per_input: 2.1,
            valid_path_ge_per_input: 2.5,
        }
    }
}

impl AreaModel {
    /// `n`:1 AOI mux over a `width`-bit datapath, including the one-hot
    /// select decoder. `n <= 1` is a wire.
    pub fn mux_ge(&self, fan_in: usize, width: u8) -> f64 {
        if fan_in <= 1 {
            return 0.0;
        }
        let tree = (fan_in as f64 - 1.0) * self.mux2_ge * width as f64;
        let decoder = fan_in as f64 * self.decoder_ge_per_out;
        tree + decoder
    }

    /// Configuration storage for an `n`:1 mux: ceil(log2 n) flop bits.
    pub fn mux_config_ge(&self, fan_in: usize) -> f64 {
        if fan_in <= 1 {
            return 0.0;
        }
        (usize::BITS - (fan_in - 1).leading_zeros()) as f64 * self.flop_ge
    }

    /// Number of configuration bits an `n`:1 mux needs.
    pub fn mux_config_bits(fan_in: usize) -> u32 {
        if fan_in <= 1 {
            0
        } else {
            usize::BITS - (fan_in - 1).leading_zeros()
        }
    }

    /// A `width`-bit register.
    pub fn register_ge(&self, width: u8) -> f64 {
        width as f64 * self.flop_ge
    }

    /// Full in-tile FIFO of `depth` entries over `width` bits: the first
    /// entry reuses the existing pipeline register; the remaining
    /// `depth-1` entries add data flops; every entry adds control.
    pub fn fifo_extra_ge(&self, depth: usize, width: u8) -> f64 {
        assert!(depth >= 1);
        (depth as f64 - 1.0) * self.register_ge(width)
            + depth as f64 * self.fifo_ctrl_ge_per_entry
    }

    /// Split-FIFO extra (Fig. 6): the second entry lives in the adjacent
    /// tile's already-existing register, so only control is added.
    pub fn split_fifo_extra_ge(&self) -> f64 {
        self.split_fifo_ctrl_ge
    }

    /// Deeper split-FIFO chain (§3.3: "we can also chain more registers
    /// together into a deeper FIFO using the same logic"): every chained
    /// entry past the first reuses a neighbouring tile's register and
    /// adds one cross-tile control stage. `chain == 2` is the classic
    /// split FIFO of Fig. 6.
    pub fn split_fifo_chain_extra_ge(&self, chain: usize) -> f64 {
        assert!(chain >= 2, "a split chain needs at least two entries");
        (chain as f64 - 1.0) * self.split_fifo_ctrl_ge
    }

    /// Ready-joining logic for a mux of `fan_in` inputs (Fig. 5,
    /// optimized variant reusing the one-hot decode).
    pub fn ready_join_ge(&self, fan_in: usize) -> f64 {
        if fan_in <= 1 {
            return 0.0;
        }
        fan_in as f64 * self.ready_join_ge_per_input
    }

    /// Valid-path mirror of a data mux (1-bit mux reusing the data mux's
    /// config).
    pub fn valid_path_ge(&self, fan_in: usize) -> f64 {
        if fan_in <= 1 {
            return 0.0;
        }
        fan_in as f64 * self.valid_path_ge_per_input
    }

    /// Convert GE to µm².
    pub fn to_um2(&self, ge: f64) -> f64 {
        ge * self.um2_per_ge
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mux_area_monotone_in_fan_in_and_width() {
        let m = AreaModel::default();
        assert_eq!(m.mux_ge(1, 16), 0.0);
        assert!(m.mux_ge(2, 16) < m.mux_ge(3, 16));
        assert!(m.mux_ge(5, 16) < m.mux_ge(5, 32));
    }

    #[test]
    fn config_bits_are_ceil_log2() {
        assert_eq!(AreaModel::mux_config_bits(1), 0);
        assert_eq!(AreaModel::mux_config_bits(2), 1);
        assert_eq!(AreaModel::mux_config_bits(5), 3);
        assert_eq!(AreaModel::mux_config_bits(8), 3);
        assert_eq!(AreaModel::mux_config_bits(9), 4);
    }

    #[test]
    fn split_fifo_cheaper_than_full_fifo() {
        let m = AreaModel::default();
        assert!(m.split_fifo_extra_ge() < m.fifo_extra_ge(2, 16));
    }

    #[test]
    fn full_fifo_depth2_dominated_by_second_data_register() {
        let m = AreaModel::default();
        let extra = m.fifo_extra_ge(2, 16);
        assert!(extra > m.register_ge(16));
    }
}
