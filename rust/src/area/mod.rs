//! Area modeling (GF12-calibrated gate-equivalent model) — the substrate
//! standing in for the paper's Global Foundries 12 nm synthesis flow.
//! See DESIGN.md §3 for the substitution rationale.

pub mod interconnect;
pub mod model;
pub mod power;

pub use interconnect::{area_of, AreaReport, FabricMode, TileArea};
pub use model::AreaModel;
pub use power::{energy_of, EnergyModel, EnergyReport};
