//! Design-space-exploration coordinator (the paper's §4 driver).
//!
//! Orchestrates parallel PnR runs across interconnect variants,
//! regenerates every figure of the paper's evaluation
//! ([`experiments`]), and owns the global-placement backend selection:
//! the AOT JAX/Pallas artifact executed through PJRT when available
//! (behind a single-owner service thread — PJRT handles are not Send),
//! the native fallback otherwise.

pub mod experiments;
pub mod viz;

pub use experiments::{
    all_experiments, alpha_sweep, fig08_fifo_area, fig09_topology, fig09_topology_with,
    fig10_area_tracks, fig10_area_tracks_with, fig11_runtime_tracks, fig11_runtime_tracks_with,
    fig13_port_area, fig14_sb_ports_runtime, fig14_sb_ports_runtime_with,
    fig15_cb_ports_runtime, fig15_cb_ports_runtime_with,
    dynamic_noc_comparison, fifo_chain_depth, motivation_shares, reg_density_sweep,
    rv_throughput, run_suite,
    tight_array, ExpOptions,
};

use std::sync::mpsc;
use std::sync::Mutex;

use crate::pnr::place::{GlobalPlacer, GlobalProblem, NativePlacer};

struct Job {
    problem: GlobalProblem,
    xs0: Vec<f32>,
    ys0: Vec<f32>,
    reply: mpsc::Sender<(Vec<f32>, Vec<f32>)>,
}

/// A `Send + Sync` front for a non-`Send` placer: a dedicated worker
/// thread owns the backend (e.g. the PJRT executable) and serves
/// `optimize` requests over a channel. PnR threads share the service.
pub struct PlacerService {
    tx: Mutex<mpsc::Sender<Job>>,
    name: &'static str,
}

impl PlacerService {
    /// Spawn a worker that constructs its backend *inside* the thread
    /// (PJRT handles never cross threads).
    pub fn spawn<F>(name: &'static str, factory: F) -> PlacerService
    where
        F: FnOnce() -> Box<dyn GlobalPlacer> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        std::thread::spawn(move || {
            let backend = factory();
            while let Ok(job) = rx.recv() {
                let out = backend.optimize(&job.problem, &job.xs0, &job.ys0);
                let _ = job.reply.send(out);
            }
        });
        PlacerService { tx: Mutex::new(tx), name }
    }
}

impl GlobalPlacer for PlacerService {
    fn optimize(&self, p: &GlobalProblem, xs0: &[f32], ys0: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .expect("placer service poisoned")
            .send(Job { problem: p.clone(), xs0: xs0.to_vec(), ys0: ys0.to_vec(), reply })
            .expect("placer service gone");
        rx.recv().expect("placer service dropped reply")
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

/// Best available global-placement backend: the AOT JAX/Pallas artifact
/// (via PJRT, wrapped in a service thread) when `artifacts/` is present;
/// the native fallback otherwise.
pub fn default_placer() -> Box<dyn GlobalPlacer + Sync + Send> {
    let dir = crate::runtime::artifacts_dir();
    if dir.join("placer_step.hlo.txt").exists() {
        Box::new(PlacerService::spawn("pjrt-jax-pallas", move || {
            match crate::runtime::PjrtPlacer::load(&dir) {
                Ok(p) => Box::new(p),
                Err(e) => {
                    eprintln!("note: PJRT placer failed to load ({e}); native fallback");
                    Box::new(NativePlacer::default())
                }
            }
        }))
    } else {
        eprintln!("note: artifacts missing; run `make artifacts` for the PJRT placer");
        Box::new(NativePlacer::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pnr::place::build_global_problem;

    #[test]
    fn placer_service_matches_native_directly() {
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3,
            reg_density: 0,
            ..Default::default()
        });
        let app = crate::pnr::pack::pack(&crate::apps::gaussian()).app;
        let p = build_global_problem(&app, &ic);
        let (xs0, ys0) = crate::pnr::place::initial_positions(&app, &ic, 3);
        let direct = NativePlacer::default().optimize(&p, &xs0, &ys0);
        let svc = PlacerService::spawn("native", || Box::new(NativePlacer::default()));
        let via = svc.optimize(&p, &xs0, &ys0);
        assert_eq!(direct, via);
        assert_eq!(svc.name(), "native");
    }

    #[test]
    fn placer_service_is_shareable_across_threads() {
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3,
            reg_density: 0,
            ..Default::default()
        });
        let app = crate::pnr::pack::pack(&crate::apps::camera()).app;
        let p = build_global_problem(&app, &ic);
        let svc = PlacerService::spawn("native", || Box::new(NativePlacer::default()));
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                let (svc, p, app, ic) = (&svc, &p, &app, &ic);
                s.spawn(move || {
                    let (xs0, ys0) = crate::pnr::place::initial_positions(app, ic, seed);
                    let (xs, ys) = svc.optimize(p, &xs0, &ys0);
                    assert_eq!(xs.len(), app.len());
                    assert_eq!(ys.len(), app.len());
                });
            }
        });
    }
}
