//! Design-space-exploration coordinator (the paper's §4 driver).
//!
//! Orchestrates parallel PnR runs across interconnect variants,
//! regenerates every figure of the paper's evaluation
//! ([`experiments`]), and owns the global-placement backend selection:
//! the AOT JAX/Pallas artifact executed through PJRT when available
//! (behind a single-owner service thread — PJRT handles are not Send),
//! the native fallback otherwise.

pub mod experiments;
pub mod viz;

pub use experiments::{
    all_experiments, alpha_sweep, fig07_hybrid_throughput, fig07_hybrid_throughput_with,
    fig08_fifo_area, fig08_fifo_area_with, fig09_topology, fig09_topology_with,
    fig10_area_tracks, fig10_area_tracks_with, fig11_runtime_tracks, fig11_runtime_tracks_with,
    fig13_port_area, fig14_sb_ports_runtime, fig14_sb_ports_runtime_with,
    fig15_cb_ports_runtime, fig15_cb_ports_runtime_with,
    dynamic_noc_comparison, fifo_chain_depth, motivation_shares, reg_density_sweep,
    rv_throughput, run_suite,
    tight_array, ExpOptions,
};

use std::sync::mpsc;
use std::sync::Mutex;

use crate::pnr::place::{BatchedNativePlacer, GlobalPlacer, GlobalProblem, PlacementInstance};

/// One problem with owned initial positions, as shipped to the service
/// thread.
type OwnedProblem = (GlobalProblem, Vec<f32>, Vec<f32>);
/// Optimized `(xs, ys)` per problem, in request order.
type Solutions = Vec<(Vec<f32>, Vec<f32>)>;

/// One request to the placer service: a whole batch of problems (a
/// single `optimize` is a one-element batch), answered in order.
struct Job {
    batch: Vec<OwnedProblem>,
    reply: mpsc::Sender<Solutions>,
}

/// A `Send + Sync` front for a non-`Send` placer: a dedicated worker
/// thread owns the backend (e.g. the PJRT executable) and serves
/// `optimize`/`place_batch` requests over a channel. PnR threads share
/// the service; batches cross the channel whole, so a batching backend
/// still sees the full group in one call.
pub struct PlacerService {
    tx: Mutex<mpsc::Sender<Job>>,
    name: &'static str,
}

impl PlacerService {
    /// Spawn a worker that constructs its backend *inside* the thread
    /// (PJRT handles never cross threads). The service reports the
    /// *backend's* `name()` — the cache identity must reflect what
    /// actually solved (e.g. a PJRT load failure falling back to the
    /// native solver must not cache under the pjrt name).
    pub fn spawn<F>(factory: F) -> PlacerService
    where
        F: FnOnce() -> Box<dyn GlobalPlacer> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let (name_tx, name_rx) = mpsc::channel();
        std::thread::spawn(move || {
            let backend = factory();
            let _ = name_tx.send(backend.name());
            while let Ok(job) = rx.recv() {
                // Single-problem requests (every `optimize`) take the
                // backend's scalar path: a batching backend must not pay
                // a padded multi-lane dispatch for one real problem.
                let out = if let [(p, xs0, ys0)] = job.batch.as_slice() {
                    vec![backend.optimize(p, xs0, ys0)]
                } else {
                    let insts: Vec<PlacementInstance> = job
                        .batch
                        .iter()
                        .map(|(p, xs0, ys0)| PlacementInstance { problem: p, xs0, ys0 })
                        .collect();
                    backend.place_batch(&insts)
                };
                let _ = job.reply.send(out);
            }
        });
        let name = name_rx.recv().expect("placer service died during construction");
        PlacerService { tx: Mutex::new(tx), name }
    }

    fn request(&self, batch: Vec<OwnedProblem>) -> Solutions {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .expect("placer service poisoned")
            .send(Job { batch, reply })
            .expect("placer service gone");
        rx.recv().expect("placer service dropped reply")
    }
}

impl GlobalPlacer for PlacerService {
    fn optimize(&self, p: &GlobalProblem, xs0: &[f32], ys0: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.request(vec![(p.clone(), xs0.to_vec(), ys0.to_vec())])
            .pop()
            .expect("placer service returned empty batch")
    }

    fn place_batch(&self, batch: &[PlacementInstance<'_>]) -> Vec<(Vec<f32>, Vec<f32>)> {
        if batch.is_empty() {
            return Vec::new();
        }
        self.request(
            batch
                .iter()
                .map(|b| (b.problem.clone(), b.xs0.to_vec(), b.ys0.to_vec()))
                .collect(),
        )
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

/// What [`default_placer`] would select, without constructing anything
/// — `canal info` and service deployments report this so an operator
/// can tell a PJRT-backed daemon from a native-fallback one before
/// issuing work.
pub fn backend_summary() -> String {
    let dir = crate::runtime::artifacts_dir();
    if dir.join("placer_step.hlo.txt").exists() {
        if cfg!(feature = "pjrt") {
            format!(
                "pjrt-jax-pallas (artifacts at {}; falls back to native-gd if the \
                 artifact fails to load)",
                dir.display()
            )
        } else {
            "native-gd (artifacts present but built without --features pjrt)".into()
        }
    } else {
        "native-gd (batched native solver; no artifacts/ — run `make artifacts` for PJRT)"
            .into()
    }
}

/// Best available global-placement backend: the AOT JAX/Pallas artifact
/// (via PJRT, wrapped in a service thread) when `artifacts/` is present;
/// the batched native solver otherwise (same math and cache identity as
/// `NativePlacer`, but DSE job groups solve in one vectorized pass).
pub fn default_placer() -> Box<dyn GlobalPlacer + Sync + Send> {
    let dir = crate::runtime::artifacts_dir();
    if dir.join("placer_step.hlo.txt").exists() {
        Box::new(PlacerService::spawn(move || {
            match crate::runtime::PjrtPlacer::load(&dir) {
                Ok(p) => Box::new(p),
                Err(e) => {
                    eprintln!("note: PJRT placer failed to load ({e}); native fallback");
                    Box::new(BatchedNativePlacer::default())
                }
            }
        }))
    } else {
        eprintln!("note: artifacts missing; run `make artifacts` for the PJRT placer");
        Box::new(BatchedNativePlacer::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pnr::place::{build_global_problem, NativePlacer};

    #[test]
    fn placer_service_matches_native_directly() {
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3,
            reg_density: 0,
            ..Default::default()
        });
        let app = crate::pnr::pack::pack(&crate::apps::gaussian()).app;
        let p = build_global_problem(&app, &ic);
        let (xs0, ys0) = crate::pnr::place::initial_positions(&app, &ic, 3);
        let direct = NativePlacer::default().optimize(&p, &xs0, &ys0);
        let svc = PlacerService::spawn(|| Box::new(NativePlacer::default()));
        let via = svc.optimize(&p, &xs0, &ys0);
        assert_eq!(direct, via);
        // The service reports its backend's cache identity, not a label.
        assert_eq!(svc.name(), "native-gd");
    }

    #[test]
    fn placer_service_forwards_batches_whole() {
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3,
            reg_density: 0,
            ..Default::default()
        });
        let apps = [crate::apps::gaussian(), crate::apps::camera()];
        let packed: Vec<_> = apps.iter().map(|a| crate::pnr::pack::pack(a).app).collect();
        let problems: Vec<_> = packed.iter().map(|a| build_global_problem(a, &ic)).collect();
        let inits: Vec<_> = packed
            .iter()
            .enumerate()
            .map(|(i, a)| crate::pnr::place::initial_positions(a, &ic, i as u64))
            .collect();
        let batch: Vec<PlacementInstance> = problems
            .iter()
            .zip(&inits)
            .map(|(p, (xs0, ys0))| PlacementInstance { problem: p, xs0, ys0 })
            .collect();
        let svc = PlacerService::spawn(|| Box::new(BatchedNativePlacer::default()));
        let via = svc.place_batch(&batch);
        let direct = BatchedNativePlacer::default().place_batch(&batch);
        assert_eq!(via, direct);
        assert!(svc.place_batch(&[]).is_empty());
    }

    #[test]
    fn placer_service_is_shareable_across_threads() {
        use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
        let ic = create_uniform_interconnect(&InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3,
            reg_density: 0,
            ..Default::default()
        });
        let app = crate::pnr::pack::pack(&crate::apps::camera()).app;
        let p = build_global_problem(&app, &ic);
        let svc = PlacerService::spawn(|| Box::new(NativePlacer::default()));
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                let (svc, p, app, ic) = (&svc, &p, &app, &ic);
                s.spawn(move || {
                    let (xs0, ys0) = crate::pnr::place::initial_positions(app, ic, seed);
                    let (xs, ys) = svc.optimize(p, &xs0, &ys0);
                    assert_eq!(xs.len(), app.len());
                    assert_eq!(ys.len(), app.len());
                });
            }
        });
    }
}
