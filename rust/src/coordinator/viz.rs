//! ASCII visualization of placements and routing utilization.
//!
//! DSE is much easier to reason about with a picture: `placement_map`
//! draws which vertex sits on which tile; `congestion_map` shades tiles
//! by how many routed nets pass through them (the routing analogue of
//! the paper's pass-through-tile discussion in Eq. 2).

use crate::ir::{CoreKind, Interconnect};
use crate::pnr::app::AppGraph;
use crate::pnr::{Placement, RoutingResult};

/// Draw the placement: `P`/`M` = PE/MEM tile hosting a vertex (letter
/// indexes the vertex), `.` = empty PE, `:` = empty MEM column tile.
pub fn placement_map(ic: &Interconnect, app: &AppGraph, placement: &Placement) -> String {
    let mut grid = vec![vec![' '; ic.width as usize]; ic.height as usize];
    for y in 0..ic.height {
        for x in 0..ic.width {
            grid[y as usize][x as usize] = match ic.tile(x, y).core.kind {
                CoreKind::Pe => '.',
                CoreKind::Mem => ':',
                CoreKind::Io => '-',
            };
        }
    }
    for (i, (id, _)) in app.iter().enumerate() {
        let (x, y) = placement.of(id);
        // a..z then A..Z then '#'
        let c = if i < 26 {
            (b'a' + i as u8) as char
        } else if i < 52 {
            (b'A' + (i - 26) as u8) as char
        } else {
            '#'
        };
        grid[y as usize][x as usize] = c;
    }
    let mut s = String::new();
    for row in grid {
        s.extend(row);
        s.push('\n');
    }
    s
}

/// Legend lines mapping glyphs to vertex names (first 52 vertices).
pub fn placement_legend(app: &AppGraph) -> String {
    let mut s = String::new();
    for (i, (_, n)) in app.iter().enumerate() {
        if i >= 52 {
            s.push_str("  ... (remaining vertices shown as '#')\n");
            break;
        }
        let c = if i < 26 { (b'a' + i as u8) as char } else { (b'A' + (i - 26) as u8) as char };
        s.push_str(&format!("  {c} = {}\n", n.name));
    }
    s
}

/// Shade tiles by routing-node usage: ` .:-=+*#%@` from idle to hot.
pub fn congestion_map(ic: &Interconnect, bit_width: u8, routing: &RoutingResult) -> String {
    let g = ic.graph(bit_width);
    let mut counts = vec![0usize; ic.width as usize * ic.height as usize];
    for tree in &routing.trees {
        for node in tree.nodes() {
            let n = g.node(node);
            counts[n.y as usize * ic.width as usize + n.x as usize] += 1;
        }
    }
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    let shades: &[u8] = b" .:-=+*#%@";
    let mut s = String::new();
    for y in 0..ic.height as usize {
        for x in 0..ic.width as usize {
            let c = counts[y * ic.width as usize + x];
            let idx = c * (shades.len() - 1) / max;
            s.push(shades[idx] as char);
        }
        s.push('\n');
    }
    s.push_str(&format!("max {} routing nodes in one tile\n", max));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::dsl::{create_uniform_interconnect, InterconnectConfig};
    use crate::pnr::{run_flow, FlowParams, SaParams};

    #[test]
    fn maps_render_with_correct_dimensions() {
        let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
        let params = FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        };
        let r = run_flow(&ic, &apps::gaussian(), &params).unwrap();
        let pm = placement_map(&ic, &r.packed.app, &r.placement);
        assert_eq!(pm.lines().count(), 8);
        assert!(pm.lines().all(|l| l.len() == 8));
        // Every placed vertex appears exactly once.
        let letters = pm.chars().filter(|c| c.is_ascii_alphabetic()).count();
        assert_eq!(letters, r.packed.app.len().min(52));

        let cm = congestion_map(&ic, 16, &r.routing);
        assert_eq!(cm.lines().count(), 9); // 8 rows + footer
        assert!(cm.contains("max"));

        let legend = placement_legend(&r.packed.app);
        assert!(legend.contains("a = "));
    }
}
