//! The paper's evaluation, experiment by experiment (§4).
//!
//! Each `figNN_*` function regenerates one table/figure of the paper's
//! evaluation section as a [`Table`]; `benches/` and the `canal
//! experiment` CLI subcommand print them. DESIGN.md §5 maps experiments
//! to modules; EXPERIMENTS.md records measured-vs-paper outcomes.
//!
//! The sweep-shaped figures (fig07/08/09/10/11/14/15) are thin formatters over
//! the [`crate::dse`] engine: each builds a [`SweepSpec`], lets the
//! sharded executor run (or cache-hit) the points, and lays the results
//! out in the paper's table shape. The `figNN_*_with` variants take a
//! caller-owned [`DseEngine`] so successive figures share one result
//! cache; the plain variants run on a throwaway in-memory engine and
//! produce the same bytes.

use crate::apps;
use crate::area::{area_of, AreaModel, FabricMode};
use crate::dse::{dense_suite_keys, suite_keys, DseEngine, SweepSpec};
use crate::dsl::{create_uniform_interconnect, ConnectedSides, InterconnectConfig, SbTopology};
use crate::pnr::{run_flow_with, FlowParams, FlowResult, GlobalPlacer, NativePlacer, SaParams};
use crate::sim::{FabricKind, RvSim, StallPattern};
use crate::util::table::{fmt, Table};

/// Shared experiment options.
#[derive(Clone)]
pub struct ExpOptions {
    /// Array size used by PnR experiments.
    pub width: u16,
    pub height: u16,
    /// SA effort (moves per node); benches lower this for wall-clock.
    pub sa_moves: usize,
    pub seed: u64,
    /// Seeds per data point in the multi-seed experiments (Fig. 9).
    pub seeds: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { width: 8, height: 8, sa_moves: 12, seed: 1, seeds: 3 }
    }
}

fn base_config(o: &ExpOptions) -> InterconnectConfig {
    InterconnectConfig {
        width: o.width,
        height: o.height,
        num_tracks: 5,
        mem_column_period: 3,
        ..Default::default()
    }
}

fn flow_params(o: &ExpOptions) -> FlowParams {
    FlowParams {
        seed: o.seed,
        sa: SaParams { moves_per_node: o.sa_moves, ..Default::default() },
        ..Default::default()
    }
}

/// Run the app suite through the flow on a given interconnect config,
/// in parallel (one thread per application). `None` = routing failed.
pub fn run_suite(
    cfg: &InterconnectConfig,
    params: &FlowParams,
    placer: &(dyn GlobalPlacer + Sync),
) -> Vec<(String, Option<FlowResult>)> {
    let ic = create_uniform_interconnect(cfg);
    let suite = apps::suite();
    std::thread::scope(|s| {
        let handles: Vec<_> = suite
            .iter()
            .map(|app| {
                let ic = &ic;
                s.spawn(move || {
                    let r = run_flow_with(ic, app, params, placer).ok();
                    (app.name.clone(), r)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("experiment thread")).collect()
    })
}

/// The §3.3 static-vs-hybrid fabric axis (Figs. 7/8): the paper's three
/// evaluated interconnect variants.
fn hybrid_fabrics() -> Vec<FabricKind> {
    vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }, FabricKind::RvSplitFifo]
}

/// Fig. 7 / §3.3: application throughput on the static vs the hybrid
/// (ready-valid) interconnect — the behavioural half of the paper's
/// static-vs-hybrid evaluation (Fig. 8 is the area half).
pub fn fig07_hybrid_throughput(o: &ExpOptions, placer: &(dyn GlobalPlacer + Sync)) -> Table {
    fig07_hybrid_throughput_with(o, placer, &mut DseEngine::in_memory())
}

/// [`fig07_hybrid_throughput`] on a caller-owned engine: every cell is a
/// cached `(config, app, seed)` point whose fabric is part of the key —
/// the executor PnRs the point and then runs the elastic simulator on
/// its own routing under the fabric's channel-capacity model.
pub fn fig07_hybrid_throughput_with(
    o: &ExpOptions,
    placer: &(dyn GlobalPlacer + Sync),
    engine: &mut DseEngine,
) -> Table {
    let spec = SweepSpec {
        name: "fig07_hybrid_throughput".into(),
        base: base_config(o),
        fabrics: hybrid_fabrics(),
        apps: suite_keys(),
        seeds: vec![o.seed],
        flow: flow_params(o),
        ..Default::default()
    };
    let out = engine.run(&spec, placer).expect("fig07 sweep");
    let mut t = Table::new(
        "Fig. 7 — static vs hybrid interconnect: elastic throughput (tokens/cycle)",
        &[
            "app",
            "static",
            "rv full fifo",
            "rv split fifo",
            "stall(static)",
            "stall(rv-full)",
            "stall(rv-split)",
        ],
    );
    // Points arrive fabric-major, app-minor (canonical order), so each
    // app's cells accumulate left-to-right across the fabric axis.
    type Cells = (Vec<String>, Vec<String>);
    let mut per_app: std::collections::BTreeMap<String, Cells> = Default::default();
    for (job, r) in &out.points {
        let (thpt, stalls) = per_app.entry(job.app_name.clone()).or_default();
        if r.routed && r.sim_cycles > 0 {
            thpt.push(format!("{:.3}", r.throughput()));
            stalls.push(r.stall_cycles.to_string());
        } else if r.routed {
            // Routed entry from a pre-fabric-axis cache: never simulated
            // (sim metrics default to 0) — don't render 0.000 as data.
            thpt.push("-".into());
            stalls.push("-".into());
        } else {
            thpt.push("unroutable".into());
            stalls.push("-".into());
        }
    }
    for (app, (thpt, stalls)) in per_app {
        let mut row = vec![app];
        row.extend(thpt);
        row.extend(stalls);
        t.row(row);
    }
    t.note("one PnR per (app, fabric) point; elastic capacity can only recover stalls");
    t.note("stall = cycles the sink spent waiting (pipeline fill + unabsorbed bubbles)");
    t
}

/// Fig. 8: SB area — static baseline vs +depth-2 FIFO vs split FIFO.
pub fn fig08_fifo_area() -> Table {
    fig08_fifo_area_with(&mut DseEngine::in_memory())
}

/// [`fig08_fifo_area`] on a caller-owned engine: an area-only sweep over
/// the fabric axis (no PnR jobs), one [`crate::dse::AreaPoint`] per
/// fabric mode. Output is byte-identical to the pre-engine formatter.
pub fn fig08_fifo_area_with(engine: &mut DseEngine) -> Table {
    let spec = SweepSpec {
        name: "fig08_fifo_area".into(),
        base: InterconnectConfig {
            width: 6,
            height: 6,
            mem_column_period: 0,
            ..Default::default()
        },
        fabrics: hybrid_fabrics(),
        area: true,
        ..Default::default()
    };
    let out = engine.run(&spec, &NativePlacer::default()).expect("fig08 sweep");
    let base = out
        .areas
        .iter()
        .find(|a| a.fabric == "static")
        .expect("fig08 sweep includes the static fabric")
        .sb_um2;
    let mut t = Table::new(
        "Fig. 8 — switch-box area: static vs ready-valid FIFOs (um^2, interior tile)",
        &["variant", "sb_area_um2", "overhead_vs_static"],
    );
    // Row labels derive from each row's own fabric, so a changed or
    // reordered fabric axis can never mislabel (or silently drop) rows.
    let variant = |label: &str| match label {
        "static" => "static (baseline)".to_string(),
        "rv-split" => "rv split FIFO".to_string(),
        other => match other.strip_prefix("rv-full:") {
            Some(d) => format!("rv full depth-{d} FIFO"),
            None => other.to_string(),
        },
    };
    for a in &out.areas {
        t.row(vec![
            variant(&a.fabric),
            fmt(a.sb_um2),
            format!("{:+.1}%", (a.sb_um2 / base - 1.0) * 100.0),
        ]);
    }
    t.note("paper: +54% full FIFO, +32% split FIFO (GF12 synthesis)");
    t
}

/// Smallest array (square-ish, with MEM columns every `mem_period`)
/// whose PE and MEM tile capacities cover the packed application with
/// `slack` headroom. Routability experiments run each app on its tight
/// array so channel pressure matches the paper's high-utilization
/// setting rather than vanishing into an oversized fabric.
pub fn tight_array(app: &crate::pnr::AppGraph, mem_period: u16, slack: f64) -> (u16, u16) {
    use crate::ir::CoreKind;
    let packed = crate::pnr::pack(app).app;
    let pe_need =
        packed.iter().filter(|(_, n)| n.op.core_kind() == CoreKind::Pe).count() as f64;
    let mem_need =
        packed.iter().filter(|(_, n)| n.op.core_kind() == CoreKind::Mem).count() as f64;
    for w in 4u16..=48 {
        let mem_cols = if mem_period == 0 { 0 } else { (0..w).step_by(mem_period as usize).count() as u16 };
        let mem_tiles = (mem_cols * w) as f64;
        let pe_tiles = (w * w) as f64 - mem_tiles;
        if pe_tiles >= pe_need * slack && mem_tiles >= mem_need * slack.max(1.0) {
            return (w, w);
        }
    }
    (48, 48)
}

/// Fig. 9 / §4.2.1: Wilton vs Disjoint routability across track counts.
///
/// The dense suite runs on a 10x10 fabric in two variants. In the
/// *pinned-output* fabric (core output `j` drives only tracks `t ≡ j`),
/// a net's starting track is fixed by its driver — the exact restriction
/// §4.2.1 blames for Disjoint's unroutability — and the paper's result
/// reproduces sharply: Wilton routes everything at five tracks while
/// Disjoint fails a large fraction. With full output fan-out
/// (`AllTracks`), a negotiation-based router can balance the disjoint
/// track planes and most of the gap closes — disclosed in the
/// third/fourth columns and in EXPERIMENTS.md.
pub fn fig09_topology(o: &ExpOptions) -> Table {
    fig09_topology_with(o, &mut DseEngine::in_memory())
}

/// [`fig09_topology`] on a caller-owned engine, so the five figure sweeps
/// can share one result cache (overlapping points run PnR once).
pub fn fig09_topology_with(o: &ExpOptions, engine: &mut DseEngine) -> Table {
    use crate::dsl::OutputTrackMode;
    let mut t = Table::new(
        "Fig. 9 — switch-box topology routability (app-runs routed / total, 3 seeds)",
        &["tracks", "wilton(pinned)", "disjoint(pinned)", "wilton(all)", "disjoint(all)"],
    );
    let apps = dense_suite_keys();
    let seeds: Vec<u64> = (0..o.seeds as u64).map(|i| o.seed + i).collect();
    let total = apps.len() * seeds.len();
    // One engine run covers the whole 3x2x2 grid: the executor shards
    // every (config, app, seed) point over the worker pool, freezing each
    // interconnect once and sharing its compiled graphs via `Arc`.
    let spec = SweepSpec {
        name: "fig09_topology".into(),
        base: InterconnectConfig {
            width: 10,
            height: 10,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks: vec![3, 4, 5],
        topologies: vec![SbTopology::Wilton, SbTopology::Disjoint],
        output_tracks: vec![OutputTrackMode::Pinned, OutputTrackMode::AllTracks],
        apps,
        seeds,
        flow: flow_params(o),
        ..Default::default()
    };
    let out = engine.run(&spec, &NativePlacer::default()).expect("fig09 sweep");
    for tracks in [3u16, 4, 5] {
        let count = |topo: SbTopology, mode: OutputTrackMode| {
            out.points
                .iter()
                .filter(|(job, r)| {
                    job.cfg.num_tracks == tracks
                        && job.cfg.sb_topology == topo
                        && job.cfg.output_tracks == mode
                        && r.routed
                })
                .count()
        };
        t.row(vec![
            tracks.to_string(),
            format!("{}/{total}", count(SbTopology::Wilton, OutputTrackMode::Pinned)),
            format!("{}/{total}", count(SbTopology::Disjoint, OutputTrackMode::Pinned)),
            format!("{}/{total}", count(SbTopology::Wilton, OutputTrackMode::AllTracks)),
            format!("{}/{total}", count(SbTopology::Disjoint, OutputTrackMode::AllTracks)),
        ]);
    }
    t.note("paper: Disjoint failed to route in all test cases; Wilton routed");
    t.note("pinned = output-track pinning (the paper's 'must only use that track number' regime)");
    t
}

/// Fig. 10: SB and CB area vs number of routing tracks.
pub fn fig10_area_tracks() -> Table {
    fig10_area_tracks_with(&mut DseEngine::in_memory())
}

/// [`fig10_area_tracks`] on a caller-owned engine (area-only sweep: the
/// engine evaluates per-config metrics, no PnR jobs).
pub fn fig10_area_tracks_with(engine: &mut DseEngine) -> Table {
    let mut t = Table::new(
        "Fig. 10 — SB and CB area vs routing tracks (um^2, interior tile)",
        &["tracks", "sb_area_um2", "cb_area_um2"],
    );
    let spec = SweepSpec {
        name: "fig10_area_tracks".into(),
        base: InterconnectConfig {
            width: 6,
            height: 6,
            mem_column_period: 0,
            ..Default::default()
        },
        tracks: (2..=8).collect(),
        area: true,
        ..Default::default()
    };
    let out = engine.run(&spec, &NativePlacer::default()).expect("fig10 sweep");
    for a in &out.areas {
        t.row(vec![a.tracks.to_string(), fmt(a.sb_um2), fmt(a.cb_um2)]);
    }
    t.note("paper: both scale with track count (SB ~linear, CB ~linear)");
    t
}

/// Fig. 11: application run time vs number of routing tracks.
///
/// Apps run on capacity-matched arrays (see [`tight_array`]): with spare
/// fabric the track count is irrelevant (routes are always minimal); under
/// pressure fewer tracks force detours → longer critical paths → longer
/// run times, the paper's <25% effect.
pub fn fig11_runtime_tracks(o: &ExpOptions, placer: &(dyn GlobalPlacer + Sync)) -> Table {
    fig11_runtime_tracks_with(o, placer, &mut DseEngine::in_memory())
}

/// [`fig11_runtime_tracks`] on a caller-owned engine.
pub fn fig11_runtime_tracks_with(
    o: &ExpOptions,
    placer: &(dyn GlobalPlacer + Sync),
    engine: &mut DseEngine,
) -> Table {
    use crate::dse::Sizing;
    let mut t = Table::new(
        "Fig. 11 — application run time vs routing tracks (us, 4096-item stream)",
        &["app", "t=3", "t=4", "t=5", "t=6", "t=7"],
    );
    let spec = SweepSpec {
        name: "fig11_runtime_tracks".into(),
        base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
        tracks: vec![3, 4, 5, 6, 7],
        sizing: Sizing::TightArray { slack: 1.25 },
        apps: dense_suite_keys(),
        seeds: vec![o.seed],
        flow: flow_params(o),
        ..Default::default()
    };
    let out = engine.run(&spec, placer).expect("fig11 sweep");
    // Points arrive tracks-major (canonical order), so each app's cells
    // accumulate left-to-right across the track axis.
    let mut per_app: std::collections::BTreeMap<String, Vec<String>> = Default::default();
    for (job, r) in &out.points {
        per_app.entry(job.app_name.clone()).or_default().push(if r.routed {
            fmt(r.runtime_us())
        } else {
            "unroutable".into()
        });
    }
    for (app, cells) in per_app {
        let mut row = vec![app];
        row.extend(cells);
        t.row(row);
    }
    t.note("paper: run time generally decreases with more tracks, by <25%");
    t
}

/// Fig. 13: SB / CB area vs number of connected core sides.
pub fn fig13_port_area() -> Table {
    let model = AreaModel::default();
    let mut t = Table::new(
        "Fig. 13 — SB and CB area vs core connection sides (um^2, interior tile)",
        &["sides", "sb_area_um2", "cb_area_um2"],
    );
    for sides in [4u8, 3, 2] {
        let cfg = InterconnectConfig {
            width: 6,
            height: 6,
            mem_column_period: 0,
            sb_core_sides: ConnectedSides(sides),
            cb_core_sides: ConnectedSides(sides),
            ..Default::default()
        };
        let ic = create_uniform_interconnect(&cfg);
        let tile = area_of(&ic, &model, FabricMode::Static).interior_tile(&ic);
        t.row(vec![sides.to_string(), fmt(tile.sb_um2), fmt(tile.cb_um2)]);
    }
    t.note("paper: fewer sides -> smaller SB (mildly) and notably smaller CB");
    t
}

/// Fig. 14: run time vs SB core-output connection sides.
pub fn fig14_sb_ports_runtime(o: &ExpOptions, placer: &(dyn GlobalPlacer + Sync)) -> Table {
    ports_runtime_with(o, placer, true, &mut DseEngine::in_memory())
}

/// Fig. 15: run time vs CB input connection sides.
pub fn fig15_cb_ports_runtime(o: &ExpOptions, placer: &(dyn GlobalPlacer + Sync)) -> Table {
    ports_runtime_with(o, placer, false, &mut DseEngine::in_memory())
}

/// [`fig14_sb_ports_runtime`] on a caller-owned engine.
pub fn fig14_sb_ports_runtime_with(
    o: &ExpOptions,
    placer: &(dyn GlobalPlacer + Sync),
    engine: &mut DseEngine,
) -> Table {
    ports_runtime_with(o, placer, true, engine)
}

/// [`fig15_cb_ports_runtime`] on a caller-owned engine.
pub fn fig15_cb_ports_runtime_with(
    o: &ExpOptions,
    placer: &(dyn GlobalPlacer + Sync),
    engine: &mut DseEngine,
) -> Table {
    ports_runtime_with(o, placer, false, engine)
}

fn ports_runtime_with(
    o: &ExpOptions,
    placer: &(dyn GlobalPlacer + Sync),
    sb: bool,
    engine: &mut DseEngine,
) -> Table {
    let what = if sb { "SB core-output" } else { "CB core-input" };
    let figno = if sb { 14 } else { 15 };
    let mut t = Table::new(
        &format!("Fig. {figno} — run time vs {what} connection sides (us)"),
        &["app", "sides=4", "sides=3", "sides=2"],
    );
    let mut spec = SweepSpec {
        name: format!("fig{figno}_ports_runtime"),
        base: base_config(o),
        apps: suite_keys(),
        seeds: vec![o.seed],
        flow: flow_params(o),
        ..Default::default()
    };
    if sb {
        spec.sb_sides = vec![4, 3, 2];
    } else {
        spec.cb_sides = vec![4, 3, 2];
    }
    let out = engine.run(&spec, placer).expect("ports sweep");
    // Points arrive sides-major, so each app's cells fill sides=4,3,2.
    let mut per_app: std::collections::BTreeMap<String, Vec<String>> = Default::default();
    for (job, r) in &out.points {
        per_app.entry(job.app_name.clone()).or_default().push(if r.routed {
            fmt(r.runtime_us())
        } else {
            "unroutable".into()
        });
    }
    for (app, cells) in per_app {
        let mut row = vec![app];
        row.extend(cells);
        t.row(row);
    }
    t.note(if sb {
        "paper: small negative effect on run time as SB sides decrease"
    } else {
        "paper: larger negative effect on run time as CB connections decrease"
    });
    t
}

/// α sweep ablation (§3.4): post-route critical path across α values.
pub fn alpha_sweep(o: &ExpOptions) -> Table {
    let mut t = Table::new(
        "Ablation — detailed-placement alpha sweep (critical path, ps)",
        &["alpha", "gaussian", "harris", "camera"],
    );
    let cfg = base_config(o);
    let ic = create_uniform_interconnect(&cfg);
    let apps: Vec<_> = ["gaussian", "harris", "camera"]
        .iter()
        .map(|n| apps::suite().into_iter().find(|a| &a.name == n).unwrap())
        .collect();
    for alpha in [1.0f64, 2.0, 4.0, 8.0, 16.0, 20.0] {
        let mut row = vec![format!("{alpha}")];
        for app in &apps {
            let params = FlowParams {
                sa: SaParams { alpha, moves_per_node: o.sa_moves, ..Default::default() },
                seed: o.seed,
                ..Default::default()
            };
            row.push(
                match run_flow_with(&ic, app, &params, &NativePlacer::default()) {
                    Ok(r) => fmt(r.timing.critical_path_ps),
                    Err(_) => "unroutable".into(),
                },
            );
        }
        t.row(row);
    }
    t.note("paper: sweeping alpha 1..20 and keeping the best post-route result");
    t
}

/// Ready-valid throughput ablation: the split FIFO behaves like the full
/// FIFO under backpressure (same elastic capacity class), both beating
/// the static fabric — the behavioural side of Fig. 8's area trade.
pub fn rv_throughput() -> Table {
    let mut t = Table::new(
        "Ablation — elastic throughput under bursty backpressure (cycles for 64 tokens)",
        &["app", "static", "rv full fifo", "rv split fifo"],
    );
    let stall = StallPattern::Bursty { accept: 3, stall: 2 };
    for app in [apps::gaussian(), apps::camera(), apps::pointwise(8)] {
        let mut row = vec![app.name.clone()];
        for fabric in [
            FabricKind::Static,
            FabricKind::RvFullFifo { depth: 2 },
            FabricKind::RvSplitFifo,
        ] {
            let caps: std::collections::HashMap<_, _> = app
                .edges()
                .iter()
                .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(1)))
                .collect();
            let input: Vec<i64> = (0..256).map(|i| (i * 13 + 5) % 199).collect();
            let run = RvSim::new(&app, &caps, input).run(64, 1_000_000, stall);
            row.push(run.cycles.to_string());
        }
        t.row(row);
    }
    t.note("elasticity (capacity > 1) absorbs burst stalls; split matches full");
    t
}

/// Ablation — split-FIFO chain depth (§3.3): chaining more registers
/// into one FIFO adds elastic capacity for only one cross-tile control
/// stage of area per entry, but the unregistered control chain lengthens
/// the combinational path ("the longer the FIFO is chained, the longer
/// the combinational delay on the path").
pub fn fifo_chain_depth() -> Table {
    use crate::sim::FabricKind;
    let cfg = InterconnectConfig { width: 6, height: 6, mem_column_period: 0, ..Default::default() };
    let ic = create_uniform_interconnect(&cfg);
    let model = AreaModel::default();
    let base = area_of(&ic, &model, FabricMode::Static).interior_tile(&ic).sb_um2;
    let full = area_of(&ic, &model, FabricMode::ReadyValidFullFifo { fifo_depth: 2 })
        .interior_tile(&ic)
        .sb_um2;
    let split = area_of(&ic, &model, FabricMode::ReadyValidSplitFifo).interior_tile(&ic).sb_um2;

    let mut t = Table::new(
        "Ablation — split-FIFO chain depth (per interior SB)",
        &["chain", "sb_area_um2", "overhead", "period_penalty_ps", "fifo_capacity"],
    );
    // Reference row: the full in-tile depth-2 FIFO of Fig. 8.
    t.row(vec![
        "full-fifo".into(),
        fmt(full),
        format!("{:+.1}%", (full / base - 1.0) * 100.0),
        fmt(0.0),
        "2".into(),
    ]);
    for chain in [2usize, 3, 4, 6] {
        // Chained control amortizes to one cross-tile stage per entry, so
        // the per-tile area is chain-independent — the paper's key win:
        // deeper elastic capacity for free area-wise...
        let area = split
            + model.to_um2(
                model.split_fifo_chain_extra_ge(chain) / (chain as f64 - 1.0)
                    - model.split_fifo_extra_ge(),
            );
        // ...but the unregistered control chain lengthens the clock
        // period (§3.3).
        let pen = FabricKind::RvSplitFifo.period_penalty_ps(chain);
        t.row(vec![
            chain.to_string(),
            fmt(area),
            format!("{:+.1}%", (area / base - 1.0) * 100.0),
            fmt(pen),
            chain.to_string(),
        ]);
    }
    t.note("deeper chains: capacity grows at flat area/tile, combinational penalty grows");
    t
}

/// Ablation — pipeline-register density (the `reg_density` axis of the
/// paper's `create_uniform_interconnect` helper, Fig. 4): fewer
/// registered tiles shrink SB area but lengthen unregistered route
/// segments, raising the critical path.
pub fn reg_density_sweep(o: &ExpOptions) -> Table {
    let model = AreaModel::default();
    let mut t = Table::new(
        "Ablation — pipeline register density (area vs critical path)",
        &["reg_density", "sb_area_um2", "gaussian_ps", "harris_ps", "camera_ps"],
    );
    for density in [0u16, 1, 2, 4] {
        let cfg = InterconnectConfig { reg_density: density, ..base_config(o) };
        let ic = create_uniform_interconnect(&cfg);
        // Mean per-tile SB area: density < 1 registers only some tiles,
        // so the interior sample would hide the savings.
        let rep = area_of(&ic, &model, FabricMode::Static);
        let sb = rep.total_sb_um2() / ic.tiles.len() as f64;
        let mut row = vec![density.to_string(), fmt(sb)];
        for name in ["gaussian", "harris", "camera"] {
            let app = apps::suite().into_iter().find(|a| a.name == name).unwrap();
            row.push(match run_flow_with(&ic, &app, &flow_params(o), &NativePlacer::default()) {
                Ok(r) => fmt(r.timing.critical_path_ps),
                Err(_) => "unroutable".into(),
            });
        }
        t.row(row);
    }
    t.note("density 0 = no interconnect registers; 1 = every tile (paper baseline)");
    t
}

/// Extension — statically-configured fabric vs generated dynamic NoC
/// (§3.3 last paragraph): same IR, routers with connectivity-derived
/// tables instead of configured muxes. Compares per-tile area and the
/// cycles to stream tokens through the app suite.
pub fn dynamic_noc_comparison(o: &ExpOptions) -> Table {
    use crate::hw::{lower_dynamic, noc_area, DynOptions};
    use crate::sim::NocSim;
    let model = AreaModel::default();
    let cfg = base_config(o);
    let ic = create_uniform_interconnect(&cfg);
    let static_tile = area_of(&ic, &model, FabricMode::Static).interior_tile(&ic);
    let noc = lower_dynamic(&ic, 16, &DynOptions::default());
    let (_, router_um2) = noc_area(&model, &noc);

    let mut t = Table::new(
        "Extension — static fabric vs dynamic NoC (same IR)",
        &["app", "static_cycles", "noc_cycles", "noc_mean_latency", "static_um2/tile", "router_um2/tile"],
    );
    let tokens = 64;
    for app in [apps::gaussian(), apps::camera(), apps::pointwise(8)] {
        let r = match run_flow_with(&ic, &app, &flow_params(o), &NativePlacer::default()) {
            Ok(r) => r,
            Err(_) => continue,
        };
        // Static: one token per cycle once the pipeline fills.
        let static_cycles = tokens + r.timing.latency_cycles;
        let packed = crate::pnr::pack(&app).app;
        let run = NocSim::new(&noc, &packed, &r.placement).run(tokens, 1, 4_000_000);
        t.row(vec![
            app.name.clone(),
            static_cycles.to_string(),
            run.cycles.to_string(),
            format!("{:.1}", run.mean_latency),
            fmt(static_tile.sb_um2 + static_tile.cb_um2),
            fmt(router_um2),
        ]);
    }
    t.note("dynamic routing trades per-tile area and hop latency for configuration-free routing");
    t
}

/// Motivation check (§1): "the reconfigurable interconnect connecting
/// these cores can constitute over 50% of the CGRA area and 25% of the
/// CGRA energy" [Vasilyev et al.]. Reports both shares for the routed
/// app suite on the paper-baseline fabric.
pub fn motivation_shares(o: &ExpOptions) -> Table {
    use crate::area::{energy_of, EnergyModel};
    // Core-area constants (µm², 12nm-class): a 16-bit 4-in/2-out PE with
    // an ALU + register file, and a dual-port line-buffer MEM macro.
    // Calibrated (like the rest of the gate-level model, DESIGN.md §3) so
    // the interconnect share of the paper-baseline fabric reproduces the
    // >50% area figure the paper cites from [Vasilyev et al.].
    const PE_CORE_UM2: f64 = 500.0;
    const MEM_CORE_UM2: f64 = 1700.0;

    let model = AreaModel::default();
    let cfg = base_config(o);
    let ic = create_uniform_interconnect(&cfg);
    let rep = area_of(&ic, &model, FabricMode::Static);
    let icn_um2 = rep.total_um2();
    let core_um2: f64 = ic
        .tiles
        .iter()
        .map(|t| match t.core.kind {
            crate::ir::CoreKind::Mem => MEM_CORE_UM2,
            _ => PE_CORE_UM2,
        })
        .sum();
    let area_share = icn_um2 / (icn_um2 + core_um2);

    let mut t = Table::new(
        "Motivation (§1) — interconnect share of CGRA area and energy",
        &["app", "area_share", "energy_share"],
    );
    for app in [apps::gaussian(), apps::harris(), apps::camera()] {
        let r = match run_flow_with(&ic, &app, &flow_params(o), &NativePlacer::default()) {
            Ok(r) => r,
            Err(_) => continue,
        };
        let e = energy_of(&ic, &r.packed, &r.routing, 16, &EnergyModel::default(), 4096);
        t.row(vec![
            app.name.clone(),
            format!("{:.0}%", area_share * 100.0),
            format!("{:.0}%", e.interconnect_share() * 100.0),
        ]);
    }
    t.note("paper cites >50% of area and ~25% of energy for the interconnect");
    t
}

/// All experiments in paper order (used by `canal experiment all`).
pub fn all_experiments(o: &ExpOptions, placer: &(dyn GlobalPlacer + Sync)) -> Vec<Table> {
    vec![
        fig07_hybrid_throughput(o, placer),
        fig08_fifo_area(),
        fig09_topology(o),
        fig10_area_tracks(),
        fig11_runtime_tracks(o, placer),
        fig13_port_area(),
        fig14_sb_ports_runtime(o, placer),
        fig15_cb_ports_runtime(o, placer),
        alpha_sweep(o),
        rv_throughput(),
        fifo_chain_depth(),
        reg_density_sweep(o),
        dynamic_noc_comparison(o),
        motivation_shares(o),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> ExpOptions {
        ExpOptions { sa_moves: 4, seeds: 1, ..Default::default() }
    }

    #[test]
    fn fig07_hybrid_fabrics_never_slower_than_static() {
        // The static-vs-hybrid behavioural claim: under identical PnR
        // (the fabric changes only channel capacities), elastic fabrics
        // match or beat the static fabric's throughput on every app.
        let t = fig07_hybrid_throughput(&quick(), &NativePlacer::default());
        assert_eq!(t.rows.len(), crate::apps::suite().len());
        let mut compared = 0;
        for r in &t.rows {
            assert_eq!(r.len(), 7);
            let cells: Vec<Option<f64>> = r[1..4].iter().map(|s| s.parse().ok()).collect();
            if let (Some(stat), Some(full), Some(split)) = (cells[0], cells[1], cells[2]) {
                assert!(stat > 0.0, "{}: static throughput {stat}", r[0]);
                assert!(full + 1e-12 >= stat, "{}: full {full} < static {stat}", r[0]);
                assert!(split + 1e-12 >= stat, "{}: split {split} < static {stat}", r[0]);
                compared += 1;
            }
        }
        assert!(compared > 0, "no routed rows to compare");
    }

    #[test]
    fn fig08_shape_matches_paper() {
        let t = fig08_fifo_area();
        assert_eq!(t.rows.len(), 3);
        // overhead ordering: full > split > 0
        let pct = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
        let full = pct(&t.rows[1][2]);
        let split = pct(&t.rows[2][2]);
        assert!(full > split && split > 0.0, "full {full} split {split}");
        assert!((full - 54.0).abs() < 10.0, "full {full}");
        assert!((split - 32.0).abs() < 10.0, "split {split}");
    }

    #[test]
    fn fig10_monotone() {
        let t = fig10_area_tracks();
        let col = |r: &Vec<String>, i: usize| r[i].parse::<f64>().unwrap();
        for w in t.rows.windows(2) {
            assert!(col(&w[1], 1) > col(&w[0], 1));
            assert!(col(&w[1], 2) > col(&w[0], 2));
        }
    }

    #[test]
    fn fig13_cb_shrinks_faster_than_sb() {
        let t = fig13_port_area();
        let v = |r: usize, c: usize| t.rows[r][c].parse::<f64>().unwrap();
        let sb_drop = 1.0 - v(2, 1) / v(0, 1);
        let cb_drop = 1.0 - v(2, 2) / v(0, 2);
        assert!(cb_drop > sb_drop, "cb {cb_drop} vs sb {sb_drop}");
        assert!(sb_drop > 0.0);
    }

    #[test]
    fn rv_throughput_elasticity_wins() {
        let t = rv_throughput();
        for r in &t.rows {
            let stat: f64 = r[1].parse().unwrap();
            let full: f64 = r[2].parse().unwrap();
            let split: f64 = r[3].parse().unwrap();
            assert!(full <= stat, "{}: full {full} vs static {stat}", r[0]);
            assert!(split <= stat, "{}: split {split} vs static {stat}", r[0]);
        }
    }

    #[test]
    fn motivation_area_share_exceeds_half() {
        let t = motivation_shares(&quick());
        assert!(!t.rows.is_empty());
        for r in &t.rows {
            let area: f64 = r[1].trim_end_matches('%').parse().unwrap();
            assert!(area >= 50.0, "{}: area share {area}%", r[0]);
            let energy: f64 = r[2].trim_end_matches('%').parse().unwrap();
            assert!((5.0..=50.0).contains(&energy), "{}: energy share {energy}%", r[0]);
        }
    }

    #[test]
    fn fifo_chain_depth_trade() {
        let t = fifo_chain_depth();
        // Area flat past chain 2; penalty strictly increasing; capacity = chain.
        let area = |i: usize| t.rows[i][1].parse::<f64>().unwrap();
        let pen = |i: usize| t.rows[i][3].parse::<f64>().unwrap();
        for i in 2..t.rows.len() {
            assert_eq!(area(i), area(1), "chain area must be flat");
            assert!(pen(i) > pen(i - 1), "penalty must grow with chain");
        }
        // The full FIFO costs more area than any split chain.
        assert!(area(0) > area(1));
    }

    #[test]
    fn dynamic_noc_slower_but_smaller() {
        let t = dynamic_noc_comparison(&quick());
        for r in &t.rows {
            let stat: f64 = r[1].parse().unwrap();
            let noc: f64 = r[2].parse().unwrap();
            assert!(noc >= stat, "{}: NoC {noc} vs static {stat}", r[0]);
            let static_um2: f64 = r[4].parse().unwrap();
            let router_um2: f64 = r[5].parse().unwrap();
            assert!(router_um2 < static_um2, "{}", r[0]);
        }
    }

    #[test]
    fn fig14_engine_cell_matches_direct_flow() {
        // The engine-ported figure must report exactly what a direct
        // (engine-free) flow run reports for the same point — the
        // "tables identical to the pre-refactor output" contract.
        let o = quick();
        let t = fig14_sb_ports_runtime(&o, &NativePlacer::default());
        let cfg = base_config(&o); // sides=4 is the default ⇒ first column
        let ic = create_uniform_interconnect(&cfg);
        let app = apps::suite().into_iter().find(|a| a.name == "gaussian").unwrap();
        let expect = match run_flow_with(&ic, &app, &flow_params(&o), &NativePlacer::default()) {
            Ok(r) => fmt(r.timing.runtime_ns / 1000.0),
            Err(_) => "unroutable".into(),
        };
        let row = t.rows.iter().find(|r| r[0] == "gaussian").unwrap();
        assert_eq!(row[1], expect);
    }

    #[test]
    fn fig11_rows_use_display_names() {
        // Registry keys (matmul3, conv_stack3) must not leak into the
        // table; rows carry the apps' display names as before.
        let o = ExpOptions { sa_moves: 2, seeds: 1, ..Default::default() };
        let t = fig11_runtime_tracks(&o, &NativePlacer::default());
        let names: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
        assert!(names.contains(&"matmul"), "{names:?}");
        assert!(names.contains(&"conv_stack"), "{names:?}");
        assert!(!names.contains(&"matmul3"), "{names:?}");
        assert_eq!(t.rows.len(), crate::apps::dense_suite().len());
        for r in &t.rows {
            assert_eq!(r.len(), 6); // app + 5 track columns
        }
    }

    #[test]
    fn fig09_wilton_geq_disjoint() {
        let t = fig09_topology(&quick());
        let parse = |s: &str| s.split('/').next().unwrap().parse::<usize>().unwrap();
        let mut strict = false;
        for r in &t.rows {
            // Pinned fabric: Wilton must dominate Disjoint on every row...
            assert!(parse(&r[1]) >= parse(&r[2]), "tracks {}: {} vs {}", r[0], r[1], r[2]);
            if parse(&r[1]) > parse(&r[2]) {
                strict = true;
            }
        }
        // ...and strictly somewhere (the paper's Fig. 9 separation).
        assert!(strict, "no strict Wilton advantage on the pinned fabric");
        // At five tracks (last row) Wilton routes everything (paper: all
        // test cases route on Wilton).
        let last = t.rows.last().unwrap();
        let total: usize = last[1].split('/').nth(1).unwrap().parse().unwrap();
        assert_eq!(parse(&last[1]), total, "wilton(pinned) at 5 tracks must route all");
    }
}
