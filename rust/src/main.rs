//! `canal` — CLI for the Canal interconnect generator.
//!
//! Subcommands mirror the paper's Fig. 2 system diagram:
//!
//! ```text
//! canal generate   --spec FILE [--backend static|rv] [--verilog OUT] [--verify]
//! canal pnr        --spec FILE --app NAME [--alpha-sweep] [--placer native|pjrt]
//! canal bitstream  --spec FILE --app NAME [--out FILE]
//! canal simulate   --app NAME [--fabric static|rv-full|rv-split] [--tokens N]
//! canal sweep      --spec FILE           # exhaustive connection sweep
//! canal experiment fig7|fig8|fig9|fig10|fig11|fig13|fig14|fig15|alpha|rv|chain|density|noc|all
//! canal dse [figures] [--smoke] [--tracks 3,4,5] [--topologies wilton,disjoint]
//!           [--sb-sides 4,3,2] [--cb-sides 4,3,2] [--out-tracks all,pinned]
//!           [--fabric static,rv-full,rv-split]
//!           [--apps a,b,c] [--seeds N] [--seed S] [--derived-seeds] [--tight SLACK]
//!           [--width W] [--height H] [--mem-period P] [--sa-moves N] [--area]
//!           [--search-core binary-heap|bucket|radix|astar|bidir] [--slack-order]
//!           [--workers N] [--cache FILE] [--no-cache] [--warm-start] [--json FILE]
//!           [--trace FILE]
//! canal tune [--smoke] [dse axis/array/flow/router/engine flags]
//!           [--archive FILE] [--no-archive] [--no-prune] [--json FILE]
//!           [--trace FILE]
//! canal serve [--addr HOST:PORT] [--workers N] [--conn-threads N]
//!             [--cache FILE] [--no-cache] [--ic-cap N] [--port-file FILE]
//!             [--read-poll MS] [--heartbeat MS]
//! canal client --addr HOST:PORT ping|info|stats|metrics|shutdown|dse|area|pnr
//!             |tune|simulate|generate|figure [--flags] [--watch]
//! canal info
//! canal help         (also: canal --help)
//! ```
//!
//! `canal dse` drives the sharded, cached design-space-exploration engine
//! (`canal::dse`): axis flags build the cross-product sweep; results are
//! cached in `dse_cache.json` (override with `--cache`, disable with
//! `--no-cache`; the file format is documented in `dse::cache`), so
//! re-runs and overlapping sweeps skip completed PnR. `canal dse figures`
//! regenerates fig07/08/09/10/11/14/15 through one shared engine; `--smoke` is
//! the CI end-to-end check (tiny 4x4 sweep, 2 workers, asserts a warm
//! re-run performs zero PnR calls). `--warm-start` turns on incremental
//! PnR (`dse::artifacts`): neighboring points warm-start from cached
//! placements and routed trees, with delta-aware sweep ordering;
//! `--smoke --warm-start` is its own end-to-end check.
//!
//! `canal tune` is search where `canal dse` is enumeration: the same
//! axis flags declare the space, but the multi-objective autotuner
//! (`canal::dse::tune`) finds its (area × period × throughput) Pareto
//! frontier with strictly fewer evaluations than the cross-product —
//! cheap-model pre-pruning, successive halving across seeds, and a
//! persisted Pareto archive (`--archive`, default sibling of the
//! result cache) that re-anchors future searches. Every evaluation
//! goes through the same cached engine, so tune and dse warm each
//! other. `canal tune --smoke` is the CI check.
//!
//! Argument parsing is hand-rolled (clap is unavailable in the offline
//! vendor set); flags are positional-order-independent `--key value`.

use std::collections::HashMap;
use std::process::ExitCode;

use canal::apps;
use canal::area::{area_of, AreaModel};
use canal::bitstream::{encode, Configuration};
use canal::coordinator::{self, ExpOptions};
use canal::dse::{
    archive_path_for, artifact_path_for, frontier_table, objectives_of, pareto_frontier,
    points_table, run_tune, tune_json, BuildFresh, DseEngine, EngineOptions, ParetoArchive,
    ParetoEntry, PnrArtifactCache, ResultsStore, SweepSpec, TuneOptions, TuneOutcome,
};
use canal::dsl::spec::{emit_spec, parse_spec};
use canal::dsl::{create_uniform_interconnect, InterconnectConfig, OutputTrackMode, SbTopology};
use canal::hw::{allocate, emit, lower_ready_valid, lower_static, verify_rtl, RvOptions};
use canal::pnr::{run_flow_with, FlowParams, NativePlacer, SaParams, SearchCore};
use canal::service::{
    Client, DseParams, Frame, GenParams, Request, ServeOptions, Server, SimParams,
    StateOptions,
};
use canal::sim::{sweep_connections, FabricKind, RvSim, StallPattern};
use canal::util::json::Json;

/// Flags that never take a value — without this list, a bare word after
/// one of them (e.g. `canal dse --no-cache figures`) would be swallowed
/// as its value instead of staying positional.
const BOOL_FLAGS: &[&str] = &[
    "verify",
    "alpha-sweep",
    "smoke",
    "no-cache",
    "area",
    "derived-seeds",
    "warm-start",
    "slack-order",
    "no-archive",
    "no-prune",
    "watch",
    "dash",
    "help",
];

struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if !BOOL_FLAGS.contains(&key)
                    && i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_config(args: &Args) -> Result<InterconnectConfig, String> {
    match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            parse_spec(&text)
        }
        None => Ok(InterconnectConfig::paper_baseline(8, 8)),
    }
}

fn find_app(name: &str) -> Result<canal::pnr::AppGraph, String> {
    let mut all = apps::suite();
    all.push(apps::matmul(3));
    all.into_iter().find(|a| a.name == name).ok_or_else(|| {
        format!("unknown app `{name}` (try: pointwise gaussian harris camera resnet matmul)")
    })
}

fn cmd_generate(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let ic = create_uniform_interconnect(&cfg);
    println!("interconnect: {}", ic.descriptor);
    println!("  nodes: {}  edges: {}", ic.node_count(), ic.edge_count());

    let backend = args.get("backend").unwrap_or("static");
    let lowered = match backend {
        "static" => lower_static(&ic),
        "rv" => lower_ready_valid(&ic, &RvOptions::default()),
        other => return Err(format!("unknown backend `{other}`")),
    };
    let hist = lowered.netlist.histogram();
    let mut kinds: Vec<_> = hist.iter().collect();
    kinds.sort();
    for (k, v) in kinds {
        println!("  {k}: {v}");
    }
    let cs = allocate(&ic);
    let total_bits: u32 = cs.bits_per_tile().values().sum();
    println!("  config bits: {total_bits}");

    let rtl = emit(&lowered.netlist);
    if args.has("verify") {
        let mismatches = verify_rtl(&ic, &rtl);
        if mismatches.is_empty() {
            println!("  structural verification: PASS");
        } else {
            for m in mismatches.iter().take(10) {
                eprintln!("  MISMATCH {}: {}", m.wire, m.reason);
            }
            return Err(format!("structural verification failed ({})", mismatches.len()));
        }
    }
    if let Some(path) = args.get("verilog") {
        std::fs::write(path, &rtl).map_err(|e| e.to_string())?;
        println!("  wrote {} ({} bytes)", path, rtl.len());
    }
    if let Some(path) = args.get("emit-spec") {
        std::fs::write(path, emit_spec(&cfg)).map_err(|e| e.to_string())?;
        println!("  wrote {path}");
    }
    Ok(())
}

fn flow_params(args: &Args) -> FlowParams {
    let mut p = FlowParams {
        sa: SaParams {
            moves_per_node: args.get("sa-moves").and_then(|v| v.parse().ok()).unwrap_or(12),
            ..Default::default()
        },
        seed: args.get("seed").and_then(|v| v.parse().ok()).unwrap_or(1),
        ..Default::default()
    };
    if args.has("alpha-sweep") {
        p.alpha_sweep = vec![1.0, 2.0, 4.0, 8.0, 16.0, 20.0];
    }
    p
}

fn cmd_pnr(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let ic = create_uniform_interconnect(&cfg);
    let app = find_app(args.get("app").ok_or("--app required")?)?;
    let params = flow_params(args);
    let placer: Box<dyn canal::pnr::GlobalPlacer + Sync + Send> =
        match args.get("placer").unwrap_or("auto") {
            "native" => Box::new(NativePlacer::default()),
            "pjrt" | "auto" => coordinator::default_placer(),
            other => return Err(format!("unknown placer `{other}`")),
        };
    let r = run_flow_with(&ic, &app, &params, placer.as_ref()).map_err(|e| e.to_string())?;
    println!("app: {} on {}", app.name, ic.descriptor);
    println!("  placer backend : {}", placer.name());
    println!("  packed vertices: {}", r.packed.app.len());
    println!("  nets routed    : {} ({} iterations)", r.routing.trees.len(), r.routing.iterations);
    println!("  wire nodes used: {}", r.routing.nodes_used);
    println!("  alpha          : {}", r.alpha);
    println!("  critical path  : {:.0} ps", r.timing.critical_path_ps);
    println!("  clock period   : {:.0} ps", r.timing.period_ps);
    println!("  latency        : {} cycles", r.timing.latency_cycles);
    println!(
        "  run time       : {:.1} us ({} items)",
        r.timing.runtime_ns / 1000.0,
        r.timing.workload_items
    );
    Ok(())
}

fn cmd_bitstream(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let ic = create_uniform_interconnect(&cfg);
    let app = find_app(args.get("app").ok_or("--app required")?)?;
    let params = flow_params(args);
    let r =
        run_flow_with(&ic, &app, &params, &NativePlacer::default()).map_err(|e| e.to_string())?;
    let config = Configuration::from_routing(&ic, 16, &r.routing)?;
    let cs = allocate(&ic);
    let bits = encode(&config, &cs);
    canal::sim::check_routing(&ic, 16, &config, &r.routing)?;
    println!("bitstream: {} words, functional check PASS", bits.len());
    if let Some(path) = args.get("out") {
        std::fs::write(path, bits.to_text()).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    } else {
        print!("{}", bits.to_text());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let app = find_app(args.get("app").ok_or("--app required")?)?;
    let raw = args.get("fabric").unwrap_or("rv-split");
    let fabric =
        FabricKind::parse(raw).ok_or_else(|| format!("unknown fabric `{raw}`"))?;
    let tokens: usize = args.get("tokens").and_then(|v| v.parse().ok()).unwrap_or(64);
    let caps: HashMap<_, _> = app
        .edges()
        .iter()
        .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(1)))
        .collect();
    let input: Vec<i64> = (0..(tokens as i64 * 4)).map(|i| (i * 13 + 5) % 199).collect();
    let stall = StallPattern::Bursty { accept: 3, stall: 2 };
    let mut sim = RvSim::new(&app, &caps, input);
    let run = sim.run(tokens, 10_000_000, stall);
    println!("app {} on {:?}: {} tokens in {} cycles", app.name, fabric, run.tokens, run.cycles);
    let mut names: Vec<_> = run.outputs.keys().collect();
    names.sort();
    for name in names {
        let seq = &run.outputs[name];
        let head: Vec<String> = seq.iter().take(8).map(|v| v.to_string()).collect();
        println!("  {name}: [{} ...] ({} tokens)", head.join(", "), seq.len());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let ic = create_uniform_interconnect(&cfg);
    let cs = allocate(&ic);
    let r = sweep_connections(&ic, Some(&cs));
    println!(
        "configuration sweep: {} connections tested, {} failures",
        r.connections_tested,
        r.failures.len()
    );
    for f in r.failures.iter().take(10) {
        eprintln!("  FAIL {f}");
    }
    if r.ok() {
        Ok(())
    } else {
        Err("sweep failed".into())
    }
}

fn cmd_experiment(args: &Args) -> Result<(), String> {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let o = ExpOptions {
        sa_moves: args.get("sa-moves").and_then(|v| v.parse().ok()).unwrap_or(12),
        ..Default::default()
    };
    let placer = coordinator::default_placer();
    let tables = match which {
        "fig7" => vec![coordinator::fig07_hybrid_throughput(&o, placer.as_ref())],
        "fig8" => vec![coordinator::fig08_fifo_area()],
        "fig9" => vec![coordinator::fig09_topology(&o)],
        "fig10" => vec![coordinator::fig10_area_tracks()],
        "fig11" => vec![coordinator::fig11_runtime_tracks(&o, placer.as_ref())],
        "fig13" => vec![coordinator::fig13_port_area()],
        "fig14" => vec![coordinator::fig14_sb_ports_runtime(&o, placer.as_ref())],
        "fig15" => vec![coordinator::fig15_cb_ports_runtime(&o, placer.as_ref())],
        "alpha" => vec![coordinator::alpha_sweep(&o)],
        "rv" => vec![coordinator::rv_throughput()],
        "chain" => vec![coordinator::fifo_chain_depth()],
        "density" => vec![coordinator::reg_density_sweep(&o)],
        "noc" => vec![coordinator::dynamic_noc_comparison(&o)],
        "motivation" => vec![coordinator::motivation_shares(&o)],
        "all" => coordinator::all_experiments(&o, placer.as_ref()),
        other => return Err(format!("unknown experiment `{other}`")),
    };
    for t in tables {
        println!("{}", t.render());
        if let Some(dir) = args.get("csv-dir") {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            let slug: String = t
                .title
                .chars()
                .take_while(|&c| c != '—')
                .filter(|c| c.is_alphanumeric())
                .collect::<String>()
                .to_lowercase();
            std::fs::write(format!("{dir}/{slug}.csv"), t.to_csv()).map_err(|e| e.to_string())?;
        }
    }
    Ok(())
}

fn parse_list<T, F: Fn(&str) -> Option<T>>(
    args: &Args,
    key: &str,
    parse: F,
) -> Result<Vec<T>, String> {
    match args.get(key) {
        None => Ok(vec![]),
        Some(raw) => raw
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| parse(s.trim()).ok_or_else(|| format!("--{key}: bad value `{s}`")))
            .collect(),
    }
}

/// The shared axis-flag → sweep-parameter mapping. `canal dse` turns
/// the result into a spec locally; `canal client dse` ships it to a
/// daemon — same flags, same semantics, same results.
fn dse_params_from_args(args: &Args) -> Result<DseParams, String> {
    let d = DseParams::default();
    Ok(DseParams {
        name: d.name,
        width: args.get("width").and_then(|v| v.parse().ok()).unwrap_or(d.width),
        height: args.get("height").and_then(|v| v.parse().ok()).unwrap_or(d.height),
        mem_period: args.get("mem-period").and_then(|v| v.parse().ok()).unwrap_or(d.mem_period),
        tracks: parse_list(args, "tracks", |s| s.parse().ok())?,
        topologies: parse_list(args, "topologies", SbTopology::parse)?,
        out_tracks: parse_list(args, "out-tracks", OutputTrackMode::parse)?,
        sb_sides: parse_list(args, "sb-sides", |s| s.parse().ok())?,
        cb_sides: parse_list(args, "cb-sides", |s| s.parse().ok())?,
        fabrics: parse_list(args, "fabric", FabricKind::parse)?,
        apps: parse_list(args, "apps", |s| Some(s.to_string()))?,
        seed: args.get("seed").and_then(|v| v.parse().ok()).unwrap_or(d.seed),
        seeds: args.get("seeds").and_then(|v| v.parse().ok()).unwrap_or(d.seeds),
        derived_seeds: args.has("derived-seeds"),
        tight: args.get("tight").and_then(|v| v.parse().ok()),
        sa_moves: args.get("sa-moves").and_then(|v| v.parse().ok()).unwrap_or(d.sa_moves),
        search_core: match args.get("search-core") {
            None => d.search_core,
            Some(raw) => SearchCore::parse(raw)
                .ok_or_else(|| {
                    format!("--search-core: bad value `{raw}` (binary-heap|bucket|radix|astar|bidir)")
                })?
                .name()
                .into(),
        },
        slack_order: args.has("slack-order"),
        area: args.has("area"),
    })
}

/// `canal dse --smoke`: the CI end-to-end check. A tiny 4x4 sweep on two
/// workers, run cold then warm against a throwaway cache file; fails if
/// the warm pass performs any PnR.
fn dse_smoke() -> Result<(), String> {
    let cache = std::env::temp_dir().join(format!("canal_dse_smoke_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&cache);
    let spec = SweepSpec {
        name: "smoke".into(),
        base: InterconnectConfig {
            width: 4,
            height: 4,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks: vec![2, 3],
        apps: vec!["pointwise4".into()],
        seeds: vec![1, 2],
        flow: canal::pnr::FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        area: true,
        ..Default::default()
    };
    let placer = NativePlacer::default();
    let run = |label: &str| -> Result<canal::dse::SweepOutcome, String> {
        // A fresh engine per pass: warm hits must come through the cache
        // *file*, proving persistence end-to-end.
        let mut engine = DseEngine::new(EngineOptions {
            workers: 2,
            cache_path: Some(cache.clone()),
            warm_start: false,
        })?;
        let out = engine.run(&spec, &placer)?;
        let s = &out.stats;
        println!(
            "smoke {label}: {} jobs, {} cached, {} PnR runs, {} configs built",
            s.jobs, s.cache_hits, s.pnr_runs, s.configs_built
        );
        Ok(out)
    };
    let cold = run("cold")?;
    let warm = run("warm")?;
    let _ = std::fs::remove_file(&cache);
    println!("{}", points_table(&warm).render());
    if cold.stats.pnr_runs != cold.stats.jobs {
        return Err(format!(
            "smoke: expected {} cold PnR runs, got {}",
            cold.stats.jobs, cold.stats.pnr_runs
        ));
    }
    if warm.stats.pnr_runs != 0 {
        return Err(format!("smoke: warm re-run performed {} PnR calls", warm.stats.pnr_runs));
    }
    for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
        if ja.key != jb.key || ra != rb {
            return Err("smoke: warm results differ from cold".into());
        }
    }
    println!("smoke: PASS (warm re-run did zero PnR, results bit-identical)");
    Ok(())
}

/// `canal dse --smoke --warm-start` — the incremental-PnR end-to-end
/// check: seed one corner point, then sweep its tracks × fabric
/// neighborhood through file-backed caches with warm starts on. The
/// fabric neighbor is the *same* PnR problem (reuse distance 1), so the
/// sweep must report `warm_starts > 0` and `nets_reused > 0`; the
/// persisted artifact store must survive a load → re-emit round trip
/// byte-identically.
fn dse_smoke_warm() -> Result<(), String> {
    let cache = std::env::temp_dir()
        .join(format!("canal_dse_smoke_warm_{}.json", std::process::id()));
    let artifacts = artifact_path_for(&cache);
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&artifacts);
    let spec = |name: &str, tracks: Vec<u16>, fabrics: Vec<FabricKind>| SweepSpec {
        name: name.into(),
        base: InterconnectConfig {
            width: 4,
            height: 4,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks,
        fabrics,
        apps: vec!["pointwise4".into()],
        seeds: vec![1],
        flow: canal::pnr::FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    let placer = NativePlacer::default();
    let engine_at = || {
        DseEngine::new(EngineOptions {
            workers: 2,
            cache_path: Some(cache.clone()),
            warm_start: true,
        })
    };
    // Pass 1: seed the donor corner (tracks=2, static fabric).
    let mut seed_engine = engine_at()?;
    let seeded = seed_engine.run(&spec("warm-seed", vec![2], vec![]), &placer)?;
    println!(
        "smoke warm seed: {} jobs, {} PnR runs, {} artifacts",
        seeded.stats.jobs,
        seeded.stats.pnr_runs,
        seed_engine.artifacts().map(|a| a.len()).unwrap_or(0)
    );
    // Pass 2: a FRESH engine over the same files sweeps the tracks ×
    // fabric neighborhood — donors must come through the artifact file.
    let mut engine = engine_at()?;
    let out = engine.run(
        &spec(
            "warm-sweep",
            vec![2, 3],
            vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }],
        ),
        &placer,
    )?;
    let s = &out.stats;
    println!(
        "smoke warm sweep: {} jobs, {} cached, {} PnR runs",
        s.jobs, s.cache_hits, s.pnr_runs
    );
    println!(
        "warm_starts={} nets_reused={} nets_rerouted={} route_expansions={}",
        s.warm_starts, s.nets_reused, s.nets_rerouted, s.route_expansions
    );
    println!("{}", points_table(&out).render());
    // Artifact round-trip: reload the persisted store and re-emit it.
    let text = std::fs::read_to_string(&artifacts)
        .map_err(|e| format!("{}: {e}", artifacts.display()))?;
    let reloaded = PnrArtifactCache::in_memory();
    let loaded = reloaded.load_json(&text);
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&artifacts);
    loaded?;
    if reloaded.to_json() != text {
        return Err("smoke: artifact cache round-trip is not byte-identical".into());
    }
    println!("artifact cache round-trip: OK");
    for (job, r) in &out.points {
        if !r.routed {
            return Err(format!("smoke: warm point failed to route: {:?}", job.key));
        }
    }
    if s.warm_starts == 0 {
        return Err("smoke: no warm starts in a neighbor sweep".into());
    }
    if s.nets_reused == 0 {
        return Err("smoke: no routed trees reused across fabric twins".into());
    }
    println!("smoke: PASS (warm starts engaged, trees reused, artifacts persisted)");
    Ok(())
}

/// `canal dse --smoke --search-core a,b,c` — the router-variant
/// end-to-end check. Runs the smoke sweep once per named core (plus the
/// `binary-heap` baseline) on fresh in-memory engines, then asserts:
/// every point routes under every core, cores that promise bit-identity
/// (`bucket`, `radix`) match the baseline point-for-point AND pop-for-pop,
/// and every core reports a nonzero `route_expansions` counter.
fn dse_smoke_variants(cores: &str) -> Result<(), String> {
    let spec_for = |core: SearchCore| SweepSpec {
        name: format!("smoke-{}", core.name()),
        base: InterconnectConfig {
            width: 4,
            height: 4,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks: vec![2, 3],
        apps: vec!["pointwise4".into()],
        seeds: vec![1, 2],
        flow: canal::pnr::FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            router: canal::pnr::RouterParams { search_core: core, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    let placer = NativePlacer::default();
    let run = |core: SearchCore| -> Result<canal::dse::SweepOutcome, String> {
        // Fresh uncached engine per core: a shared cache would answer
        // bit-identical cores from the baseline's entries and the core
        // under test would never execute.
        let mut engine = DseEngine::in_memory();
        let out = engine.run(&spec_for(core), &placer)?;
        let s = &out.stats;
        println!(
            "smoke variant: core={} jobs={} pnr_runs={} route_expansions={}",
            core.name(),
            s.jobs,
            s.pnr_runs,
            s.route_expansions
        );
        if s.route_expansions == 0 {
            return Err(format!("smoke: core `{}` reported zero route_expansions", core.name()));
        }
        for (job, r) in &out.points {
            if !r.routed {
                return Err(format!(
                    "smoke: core `{}` failed to route {:?}",
                    core.name(),
                    job.key
                ));
            }
        }
        Ok(out)
    };
    let base = run(SearchCore::BinaryHeap)?;
    let mut identical: Vec<&'static str> = Vec::new();
    let mut routed: Vec<&'static str> = vec![SearchCore::BinaryHeap.name()];
    for raw in cores.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let core = SearchCore::parse(raw)
            .ok_or_else(|| format!("--search-core: bad value `{raw}`"))?;
        if core == SearchCore::BinaryHeap {
            continue; // already the baseline
        }
        let out = run(core)?;
        if !core.changes_results() {
            if out.stats.route_expansions != base.stats.route_expansions {
                return Err(format!(
                    "smoke: core `{}` expansions {} != baseline {}",
                    core.name(),
                    out.stats.route_expansions,
                    base.stats.route_expansions
                ));
            }
            for ((ja, ra), (jb, rb)) in base.points.iter().zip(&out.points) {
                if ja.key.config != jb.key.config || ra != rb {
                    return Err(format!(
                        "smoke: core `{}` diverged from binary-heap on {:?}",
                        core.name(),
                        ja.key
                    ));
                }
            }
            identical.push(core.name());
        }
        routed.push(core.name());
    }
    println!(
        "smoke variants: PASS (bit-identity holds for [{}] vs binary-heap; cores routed: {})",
        identical.join(","),
        routed.join(",")
    );
    Ok(())
}

/// Regenerate the engine-backed figures through one shared engine, so
/// overlapping points across figures are PnR'd once.
fn dse_figures(args: &Args, engine: &mut DseEngine) -> Result<(), String> {
    let o = ExpOptions {
        sa_moves: args.get("sa-moves").and_then(|v| v.parse().ok()).unwrap_or(12),
        ..Default::default()
    };
    let placer = coordinator::default_placer();
    println!("{}", coordinator::fig07_hybrid_throughput_with(&o, placer.as_ref(), engine).render());
    println!("{}", coordinator::fig08_fifo_area_with(engine).render());
    println!("{}", coordinator::fig09_topology_with(&o, engine).render());
    println!("{}", coordinator::fig10_area_tracks_with(engine).render());
    println!("{}", coordinator::fig11_runtime_tracks_with(&o, placer.as_ref(), engine).render());
    println!("{}", coordinator::fig14_sb_ports_runtime_with(&o, placer.as_ref(), engine).render());
    println!("{}", coordinator::fig15_cb_ports_runtime_with(&o, placer.as_ref(), engine).render());
    let s = engine.lifetime_stats();
    println!(
        "engine: {} jobs, {} cached, {} PnR runs, {} sims, {} configs built, \
         {} batched solves, {} steals, {} cache entries",
        s.jobs,
        s.cache_hits,
        s.pnr_runs,
        s.sims,
        s.configs_built,
        s.batched_solves,
        s.steals,
        engine.cache().len()
    );
    Ok(())
}

/// `canal dse --trace FILE`: run the sweep with the observability gate
/// fully open, then write the merged Chrome trace and print the metrics
/// snapshot (NDJSON, one metric per line) to stdout. Works with every
/// dse form, `--smoke` included — that pairing is the CI trace check.
fn cmd_dse(args: &Args) -> Result<(), String> {
    let trace = args.get("trace").map(std::path::PathBuf::from);
    if trace.is_some() {
        canal::obs::ObsOptions::full().apply();
    }
    let result = cmd_dse_untraced(args);
    if let Some(path) = &trace {
        // Export even when the sweep failed: a partial trace of a
        // failing run is exactly what you want to look at.
        canal::obs::export::write_chrome_trace(path)?;
        println!("wrote trace {}", path.display());
        print!("{}", canal::obs::export::metrics_ndjson());
    }
    result
}

fn cmd_dse_untraced(args: &Args) -> Result<(), String> {
    if args.has("smoke") {
        if args.has("warm-start") {
            return dse_smoke_warm();
        }
        if let Some(cores) = args.get("search-core") {
            return dse_smoke_variants(cores);
        }
        return dse_smoke();
    }
    let workers = args.get("workers").and_then(|v| v.parse().ok()).unwrap_or(0);
    let cache_path = if args.has("no-cache") {
        None
    } else {
        Some(args.get("cache").unwrap_or("dse_cache.json").into())
    };
    let warm_start = args.has("warm-start");
    let mut engine = DseEngine::new(EngineOptions { workers, cache_path, warm_start })?;

    if args.positional.get(1).map(String::as_str) == Some("figures") {
        return dse_figures(args, &mut engine);
    }

    // Ad-hoc sweep from axis flags. `DseParams` is the service
    // protocol's sweep-request type; building the CLI spec through it
    // keeps `canal dse` and a daemon `dse` request on ONE construction
    // path — the bit-identity contract between the two depends on that.
    let spec = dse_params_from_args(args)?.to_spec();
    if spec.apps.is_empty() && !spec.area {
        return Err("nothing to do: pass --apps a,b,c and/or --area".into());
    }
    let placer = coordinator::default_placer();
    let out = engine.run(&spec, placer.as_ref())?;
    let mut store = ResultsStore::new();
    let table = points_table(&out);
    if spec.area {
        let areas = canal::dse::areas_table(&out);
        println!("{}", areas.render());
    }
    store.add(&out, table.clone());
    println!("{}", table.render());
    if let Some(path) = args.get("json") {
        store.write_json(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `canal tune`: the multi-objective Pareto autotuner over the cached
/// DSE engine. Same axis flags as `canal dse` (the spec IS the search
/// space), plus the archive knobs; `--trace FILE` composes exactly as
/// it does for `dse`.
fn cmd_tune(args: &Args) -> Result<(), String> {
    let trace = args.get("trace").map(std::path::PathBuf::from);
    if trace.is_some() {
        canal::obs::ObsOptions::full().apply();
    }
    let result = cmd_tune_untraced(args);
    if let Some(path) = &trace {
        canal::obs::export::write_chrome_trace(path)?;
        println!("wrote trace {}", path.display());
        print!("{}", canal::obs::export::metrics_ndjson());
    }
    result
}

fn cmd_tune_untraced(args: &Args) -> Result<(), String> {
    if args.has("smoke") {
        return tune_smoke();
    }
    let workers = args.get("workers").and_then(|v| v.parse().ok()).unwrap_or(0);
    let cache_path: Option<std::path::PathBuf> = if args.has("no-cache") {
        None
    } else {
        Some(args.get("cache").unwrap_or("dse_cache.json").into())
    };
    // Archive resolution: explicit `--archive FILE` wins; otherwise it
    // sits next to the result cache (`dse_cache_pareto.json`), or at
    // `pareto_archive.json` when the cache is off; `--no-archive`
    // searches from scratch and persists nothing.
    let mut archive = if args.has("no-archive") {
        ParetoArchive::in_memory()
    } else {
        let path = match args.get("archive") {
            Some(p) => std::path::PathBuf::from(p),
            None => match &cache_path {
                Some(cache) => archive_path_for(cache),
                None => std::path::PathBuf::from("pareto_archive.json"),
            },
        };
        ParetoArchive::at(&path)?
    };
    let spec = dse_params_from_args(args)?.to_spec();
    if spec.apps.is_empty() {
        return Err("nothing to tune: pass --apps a,b,c".into());
    }
    let mut engine = DseEngine::new(EngineOptions {
        workers,
        cache_path,
        warm_start: args.has("warm-start"),
    })?;
    let placer = coordinator::default_placer();
    let opts = TuneOptions { prune: !args.has("no-prune") };
    let out = run_tune(&spec, placer.name(), &BuildFresh, &mut archive, &opts, &mut |s| {
        engine.run(s, placer.as_ref())
    })?;
    println!("{}", frontier_table(&out).render());
    if let Some(path) = args.get("json") {
        std::fs::write(path, tune_json(&out).render())
            .map_err(|e| format!("{path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Fold one full enumerating sweep into per-(config, app) aggregates
/// and filter to the Pareto frontier — the exhaustive reference
/// [`tune_smoke`] checks the search against.
fn exhaustive_frontier(out: &canal::dse::SweepOutcome) -> Vec<ParetoEntry> {
    let model = AreaModel::default();
    let mut areas: HashMap<String, f64> = HashMap::new();
    let mut agg: std::collections::BTreeMap<(String, String), ParetoEntry> =
        std::collections::BTreeMap::new();
    for (job, r) in &out.points {
        // Keyed by the FULL descriptor: area depends on the fabric mode
        // too, and the descriptor is the only string that carries both.
        let area = *areas.entry(job.key.config.0.clone()).or_insert_with(|| {
            let ic = create_uniform_interconnect(&job.cfg);
            area_of(&ic, &model, job.fabric.area_mode()).interior_tile(&ic).total()
        });
        let o = objectives_of(r, area);
        let key = (job.key.config.0.clone(), job.key.app.clone());
        match agg.get_mut(&key) {
            Some(e) => {
                e.objectives.fold(&o);
                if let Err(at) = e.seeds.binary_search(&job.key.seed) {
                    e.seeds.insert(at, job.key.seed);
                }
            }
            None => {
                agg.insert(
                    key,
                    ParetoEntry {
                        config: job.key.config.0.clone(),
                        app: job.key.app.clone(),
                        fabric: job.fabric.label(),
                        objectives: o,
                        seeds: vec![job.key.seed],
                    },
                );
            }
        }
    }
    let entries: Vec<ParetoEntry> =
        agg.into_values().filter(|e| e.objectives.is_finite()).collect();
    pareto_frontier(&entries)
}

/// `canal tune --smoke`: the CI search-beats-enumeration check. One
/// tiny tracks-axis space, cold-tuned through a throwaway cache +
/// archive, then checked on three contracts: the tuned frontier equals
/// the exhaustive sweep's frontier exactly; the search evaluated
/// strictly fewer points than the cross-product; and a warm re-tune
/// performs zero PnR and zero sims. The printed `evaluations=`/
/// `cross_product=` lines are what CI greps.
fn tune_smoke() -> Result<(), String> {
    let cache =
        std::env::temp_dir().join(format!("canal_tune_smoke_{}.json", std::process::id()));
    let archive_path = archive_path_for(&cache);
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&archive_path);
    let spec = SweepSpec {
        name: "tune-smoke".into(),
        base: InterconnectConfig {
            width: 4,
            height: 4,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks: vec![2, 3],
        apps: vec!["pointwise4".into()],
        seeds: vec![1, 2],
        flow: canal::pnr::FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    let placer = NativePlacer::default();
    let run = |label: &str| -> Result<TuneOutcome, String> {
        // Fresh engine + freshly loaded archive per pass: warmth must
        // come through the files, proving persistence end-to-end.
        let mut engine = DseEngine::new(EngineOptions {
            workers: 2,
            cache_path: Some(cache.clone()),
            warm_start: false,
        })?;
        let mut archive = ParetoArchive::at(&archive_path)?;
        let out = run_tune(
            &spec,
            placer.name(),
            &BuildFresh,
            &mut archive,
            &TuneOptions::default(),
            &mut |s| engine.run(s, &placer),
        )?;
        println!(
            "tune smoke {label}: evaluations={} cross_product={} pruned={} dropped={} \
             rounds={} pnr_runs={} sims={} cache_hits={}",
            out.evaluated,
            out.cross_product,
            out.pruned,
            out.dropped,
            out.rounds,
            out.stats.pnr_runs,
            out.stats.sims,
            out.stats.cache_hits
        );
        Ok(out)
    };
    let check = (|| -> Result<(), String> {
        let cold = run("cold")?;
        println!("{}", frontier_table(&cold).render());
        if cold.evaluated >= cold.cross_product {
            return Err(format!(
                "tune smoke: search did not beat enumeration ({} evaluations vs {} \
                 cross-product)",
                cold.evaluated, cold.cross_product
            ));
        }
        if cold.frontier.is_empty() {
            return Err("tune smoke: empty frontier".into());
        }
        // Exhaustive reference over the same (now-warm) cache file: the
        // tuned frontier must be exactly the full sweep's frontier.
        let mut engine = DseEngine::new(EngineOptions {
            workers: 2,
            cache_path: Some(cache.clone()),
            warm_start: false,
        })?;
        let full = engine.run(&spec, &placer)?;
        let reference = exhaustive_frontier(&full);
        if cold.frontier != reference {
            return Err(format!(
                "tune smoke: tuned frontier ({} entries) differs from the exhaustive \
                 frontier ({} entries)",
                cold.frontier.len(),
                reference.len()
            ));
        }
        // The persisted archive parses back byte-identically.
        let text = std::fs::read_to_string(&archive_path)
            .map_err(|e| format!("{}: {e}", archive_path.display()))?;
        let mut reloaded = ParetoArchive::in_memory();
        reloaded.load_json(&text)?;
        if reloaded.to_json() != text {
            return Err("tune smoke: archive round-trip is not byte-identical".into());
        }
        // Warm re-tune: every evaluation is a cache hit.
        let warm = run("warm")?;
        if warm.stats.pnr_runs != 0 || warm.stats.sims != 0 {
            return Err(format!(
                "tune smoke: warm re-tune ran {} PnR calls and {} sims",
                warm.stats.pnr_runs, warm.stats.sims
            ));
        }
        if warm.frontier != cold.frontier {
            return Err("tune smoke: warm frontier differs from cold".into());
        }
        Ok(())
    })();
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&archive_path);
    check?;
    println!(
        "tune smoke: PASS (frontier exact, search beat enumeration, warm re-tune did \
         zero PnR, archive round-trips)"
    );
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    println!("canal {} — CGRA interconnect generator", env!("CARGO_PKG_VERSION"));
    // Compiled feature flags + the placement backend `auto` would pick:
    // what a service deployment needs to know before issuing work.
    println!("  features: pjrt={}", if cfg!(feature = "pjrt") { "on" } else { "off" });
    println!("  placer backend: {}", coordinator::backend_summary());
    match canal::runtime::PjrtPlacer::load_default() {
        Ok(p) => {
            let m = p.meta();
            println!(
                "  pjrt: {} (pad_n={} pad_m={} pad_k={} inner_steps={})",
                p.platform(),
                m.pad_n,
                m.pad_m,
                m.pad_k,
                m.inner_steps
            );
        }
        Err(e) => println!("  pjrt: unavailable ({e})"),
    }
    println!("  apps: {}", canal::dse::registry_keys().join(" "));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cache_path = if args.has("no-cache") {
        None
    } else {
        Some(args.get("cache").unwrap_or("dse_cache.json").into())
    };
    let d = ServeOptions::default();
    let millis = |key: &str, fallback: std::time::Duration| {
        args.get(key)
            .and_then(|v| v.parse().ok())
            .map(std::time::Duration::from_millis)
            .unwrap_or(fallback)
    };
    let opts = ServeOptions {
        addr: args.get("addr").unwrap_or("127.0.0.1:9000").to_string(),
        conn_threads: args.get("conn-threads").and_then(|v| v.parse().ok()).unwrap_or(0),
        state: StateOptions {
            workers: args.get("workers").and_then(|v| v.parse().ok()).unwrap_or(0),
            cache_path,
            ic_capacity: args.get("ic-cap").and_then(|v| v.parse().ok()).unwrap_or(32),
        },
        port_file: args.get("port-file").map(Into::into),
        read_poll: millis("read-poll", d.read_poll),
        heartbeat: millis("heartbeat", d.heartbeat),
    };
    let server = Server::bind(opts)?;
    let addr = server.local_addr()?;
    println!("canal serve: listening on {addr}");
    println!("  placer backend: {}", server.state().placer_name());
    server.run()?;
    println!("canal serve: drained and flushed, exiting");
    Ok(())
}

fn cmd_client(args: &Args) -> Result<(), String> {
    let addr = args.get("addr").ok_or("--addr HOST:PORT required")?;
    let dash = args.has("dash");
    // `--dash` with no subcommand is the terminal dashboard: a `watch`
    // stream rendered as sparklines.
    let sub = match args.positional.get(1).map(String::as_str) {
        Some(s) => s,
        None if dash => "watch",
        None => {
            return Err("client: missing command \
                 (ping|info|stats|metrics|history|watch|generate|pnr|simulate|dse|area|\
                 tune|figure|shutdown)"
                .into())
        }
    };
    let req = match sub {
        "ping" => Request::Ping,
        "info" => Request::Info,
        "stats" => Request::Stats,
        "metrics" => Request::Metrics,
        "history" => Request::History,
        "watch" => Request::Watch,
        "shutdown" => Request::Shutdown,
        "dse" => Request::Dse(dse_params_from_args(args)?),
        "area" => Request::Area(dse_params_from_args(args)?),
        "tune" => Request::Tune(dse_params_from_args(args)?),
        "pnr" => {
            let app = args.get("app").ok_or("--app required")?;
            let mut p = dse_params_from_args(args)?;
            p.apps = vec![app.to_string()];
            Request::Pnr(p)
        }
        "simulate" => {
            let raw = args.get("fabric").unwrap_or("rv-split");
            Request::Simulate(SimParams {
                app: args.get("app").ok_or("--app required")?.to_string(),
                fabric: FabricKind::parse(raw)
                    .ok_or_else(|| format!("unknown fabric `{raw}`"))?,
                tokens: args.get("tokens").and_then(|v| v.parse().ok()).unwrap_or(64),
            })
        }
        "generate" => {
            let d = GenParams::default();
            Request::Generate(GenParams {
                width: args.get("width").and_then(|v| v.parse().ok()).unwrap_or(d.width),
                height: args.get("height").and_then(|v| v.parse().ok()).unwrap_or(d.height),
                mem_period: args
                    .get("mem-period")
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(d.mem_period),
                tracks: args.get("tracks").and_then(|v| v.parse().ok()),
                topology: match args.get("topology") {
                    None => None,
                    Some(s) => Some(
                        SbTopology::parse(s).ok_or_else(|| format!("unknown topology `{s}`"))?,
                    ),
                },
                backend: args.get("backend").unwrap_or("static").to_string(),
            })
        }
        "figure" => Request::Figure {
            which: args
                .positional
                .get(2)
                .cloned()
                .ok_or("client figure: name one of fig7|fig8|fig9|fig10|fig11|fig14|fig15")?,
            sa_moves: args.get("sa-moves").and_then(|v| v.parse().ok()).unwrap_or(12),
        },
        other => return Err(format!("unknown client command `{other}`")),
    };
    let mut client = Client::connect(addr)?;
    if matches!(req, Request::Watch) {
        return client_watch(&mut client, dash);
    }
    // `--watch` promotes progress frames to stdout: during a long sweep
    // the daemon heartbeats live progress (jobs done/total, cache hits,
    // coalesced joins, per-worker utilization) every `--heartbeat`.
    let watch = args.has("watch");
    let data = client.call_with(&req, |msg| {
        if watch {
            println!("{msg}");
        } else {
            eprintln!("… {msg}");
        }
    })?;
    // `metrics` prints one metric object per line (same shape as the
    // NDJSON snapshot `canal dse --trace` emits) — grep-friendly — then
    // a derived one-liner (latency quantiles + cache hit rate).
    if let Some(Json::Arr(metrics)) = data.get("metrics") {
        for m in metrics {
            println!("{}", m.render_line());
        }
        if let Some(summary) = metrics_summary(metrics) {
            println!("{summary}");
        }
        return Ok(());
    }
    // Prefer server-rendered tables; fall back to the raw JSON record.
    if let Some(table) = data.get("table").and_then(Json::as_str) {
        if let Some(at) = data.get("areas_table").and_then(Json::as_str) {
            println!("{at}");
        }
        println!("{table}");
        if let Some(stats) = data.get("stats") {
            println!("stats: {}", stats.render_line());
        }
    } else {
        println!("{}", data.render_line());
        if sub == "stats" {
            if let Some(summary) = stats_summary(&data) {
                println!("{summary}");
            }
        }
    }
    Ok(())
}

/// How many points each `--dash` sparkline keeps (one terminal line).
const DASH_POINTS: usize = 24;

/// Stream `watch` frames until the daemon closes the connection.
/// Plain `watch` prints each history sample as an NDJSON line; `--dash`
/// renders a live sparkline line per sample instead.
fn client_watch(client: &mut Client, dash: bool) -> Result<(), String> {
    let mut req_series: Vec<f64> = Vec::new();
    let mut p50_series: Vec<f64> = Vec::new();
    let mut hit_series: Vec<f64> = Vec::new();
    let outcome = client.call_frames(&Request::Watch, |frame| {
        let Frame::History { data, .. } = frame else { return true };
        let Some(Json::Arr(samples)) = data.get("samples") else { return true };
        for s in samples {
            if !dash {
                println!("{}", s.render_line());
                continue;
            }
            let counters = s.get("counters");
            let req = sum_counter_prefix(counters, "service.request.");
            let hits = sum_counter_prefix(counters, "engine.cache_hits");
            let jobs = sum_counter_prefix(counters, "engine.jobs");
            let p50 = s
                .get("quantiles")
                .and_then(|q| q.get("service.request.latency_us"))
                .and_then(|h| h.get("p50"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0);
            push_capped(&mut req_series, req);
            push_capped(&mut p50_series, p50);
            push_capped(&mut hit_series, if jobs > 0.0 { hits / jobs * 100.0 } else { 0.0 });
            let live =
                s.get("progress").map(progress_cell).unwrap_or_else(|| "idle".into());
            println!(
                "req {} {:>4} │ p50µs {} {:>6.0} │ hit% {} {:>3.0} │ {live}",
                sparkline(&req_series),
                req,
                sparkline(&p50_series),
                p50,
                sparkline(&hit_series),
                hit_series.last().copied().unwrap_or(0.0),
            );
        }
        true
    });
    match outcome {
        Ok(Some(Frame::Error { error, .. })) => Err(error),
        Ok(_) => Ok(()),
        // The stream's clean end IS a disconnect: the daemon drained.
        Err(e) if e.contains("connection closed") => {
            eprintln!("watch: daemon closed the connection");
            Ok(())
        }
        Err(e) => Err(e),
    }
}

fn push_capped(series: &mut Vec<f64>, v: f64) {
    series.push(v);
    if series.len() > DASH_POINTS {
        series.remove(0);
    }
}

/// Unicode sparkline scaled to the series' own maximum.
fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().copied().filter(|v| v.is_finite()).fold(0.0f64, f64::max);
    values
        .iter()
        .map(|&v| {
            if max <= 0.0 || !v.is_finite() || v <= 0.0 {
                BARS[0]
            } else {
                BARS[((v / max * 7.0).round() as usize).min(7)]
            }
        })
        .collect()
}

/// Sum of the counter-delta fields of one history sample whose name
/// starts with `prefix`.
fn sum_counter_prefix(counters: Option<&Json>, prefix: &str) -> f64 {
    match counters {
        Some(Json::Obj(members)) => members
            .iter()
            .filter(|(n, _)| n.starts_with(prefix))
            .filter_map(|(_, v)| v.as_f64())
            .sum(),
        _ => 0.0,
    }
}

/// The live-sweep cell of one `--dash` line.
fn progress_cell(p: &Json) -> String {
    let g = |k: &str| p.get(k).and_then(Json::as_u64).unwrap_or(0);
    let mut s = format!("jobs {}/{}", g("jobs_done"), g("jobs_total"));
    if let Some(Json::Arr(util)) = p.get("util") {
        for (i, u) in util.iter().enumerate() {
            s.push_str(&format!(" w{i}={}%", u.as_u64().unwrap_or(0)));
        }
    }
    s
}

/// Derived one-liner under `canal client metrics`: request-latency
/// quantiles and the lifetime DSE cache hit rate.
fn metrics_summary(metrics: &[Json]) -> Option<String> {
    let find = |name: &str| {
        metrics.iter().find(|m| m.get("metric").and_then(Json::as_str) == Some(name))
    };
    let mut parts: Vec<String> = Vec::new();
    if let Some(h) = find("service.request.latency_us") {
        let q = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        parts.push(format!(
            "latency µs p50={:.0} p90={:.0} p99={:.0} (n={})",
            q("p50"),
            q("p90"),
            q("p99"),
            h.get("count").and_then(Json::as_u64).unwrap_or(0)
        ));
    }
    let counter_of = |name: &str| {
        find(name).and_then(|m| m.get("value")).and_then(Json::as_u64).unwrap_or(0)
    };
    let (hits, jobs) = (counter_of("engine.cache_hits"), counter_of("engine.jobs"));
    if jobs > 0 {
        parts.push(format!(
            "cache hit rate {:.1}% ({hits}/{jobs})",
            hits as f64 / jobs as f64 * 100.0
        ));
    }
    if parts.is_empty() {
        None
    } else {
        Some(format!("summary: {}", parts.join(" · ")))
    }
}

/// Derived one-liner under `canal client stats`.
fn stats_summary(data: &Json) -> Option<String> {
    let g = |k: &str| data.get(k).and_then(Json::as_u64);
    let jobs = g("jobs")?;
    if jobs == 0 {
        return None;
    }
    let hits = g("cache_hits").unwrap_or(0);
    Some(format!(
        "summary: cache hit rate {:.1}% ({hits}/{jobs} jobs) · {} coalesced · {} PnR \
         runs · {} warm starts",
        hits as f64 / jobs as f64 * 100.0,
        g("coalesced").unwrap_or(0),
        g("pnr_runs").unwrap_or(0),
        g("warm_starts").unwrap_or(0),
    ))
}

/// Full usage text. Keep in lockstep with `docs/cli.md`, which embeds
/// this block verbatim.
const USAGE: &str = "canal — CGRA interconnect generator (Canal reproduction)

usage: canal <command> [--flags]

commands:
  generate    build an interconnect and lower it to hardware
              --spec FILE  --backend static|rv  --verilog OUT  --emit-spec OUT  --verify
  pnr         place and route one application
              --spec FILE  --app NAME  --seed N  --sa-moves N  --alpha-sweep
              --placer native|pjrt|auto
  bitstream   PnR + encode a configuration bitstream
              --spec FILE  --app NAME  --seed N  --sa-moves N  --out FILE
  simulate    cycle-accurate ready-valid simulation of an application
              --app NAME  --fabric static|rv-full|rv-split  --tokens N
  sweep       exhaustive connection sweep (configuration-space check)
              --spec FILE
  experiment  reproduce a paper figure or table:
              fig7|fig8|fig9|fig10|fig11|fig13|fig14|fig15|alpha|rv|chain|density|noc|motivation|all
              --sa-moves N  --csv-dir DIR
  dse         sharded, cached, batch-placed design-space exploration
              axes:   --tracks 3,4,5  --topologies wilton,disjoint,imran
                      --sb-sides 4,3,2  --cb-sides 4,3,2  --out-tracks all,pinned
                      --fabric static,rv-full,rv-split  --apps a,b,c
                      --seeds N  --seed S  --derived-seeds
              array:  --width W  --height H  --mem-period P  --tight SLACK
              flow:   --sa-moves N  --area
              router: --search-core binary-heap|bucket|radix|astar|bidir
                      --slack-order (STA-driven net order between router iterations)
              engine: --workers N  --cache FILE  --no-cache  --warm-start  --json FILE
              (--warm-start: incremental PnR — warm-start neighboring points from
               cached placements + routed trees, delta-aware sweep ordering)
              --trace FILE: record the run, write a Chrome trace-event file
               (loads in Perfetto, one track per worker), print metrics NDJSON
  dse figures  regenerate fig07/08/09/10/11/14/15 through one shared result cache
  dse --smoke  CI end-to-end check (tiny 4x4 sweep, 2 workers, warm re-run = 0 PnR)
               with --warm-start: incremental-PnR check (warm_starts > 0,
               nets_reused > 0, artifact store round-trips byte-identically)
               with --search-core a,b,c: router-variant check (every core routes
               every point, bucket/radix stay bit-identical to binary-heap,
               route_expansions counters are live)
               with --trace FILE: the CI trace check (span + metric coverage)
  tune        multi-objective Pareto autotuner: search, not enumeration — finds
              the (area x period x throughput) frontier of the same axis space
              `dse` would enumerate, with strictly fewer evaluations
              (cheap-model pre-pruning, successive halving across seeds,
              persisted Pareto archive re-anchoring future searches)
              axes/array/flow/router/engine flags: exactly as `dse`
              --archive FILE  (default: `_pareto` sibling of the result cache)
              --no-archive    search from scratch, persist nothing
              --no-prune      disable cheap-model pre-pruning
              --json FILE     machine-readable frontier + search stats
              --trace FILE    record the run (same contract as `dse --trace`)
  tune --smoke CI search-beats-enumeration check: tuned frontier == exhaustive
               frontier, evaluations < cross-product, warm re-tune = 0 PnR,
               archive round-trips byte-identically
  serve       persistent daemon: concurrent sessions, one shared warm cache,
              coalesced in-flight sweeps (newline-delimited JSON over TCP),
              embedded dashboard on the same port for HTTP clients:
              GET /dash (self-contained HTML+SVG), /metrics.json,
              /history.json, /archive.json
              --addr HOST:PORT  --workers N  --conn-threads N  --cache FILE
              --no-cache  --ic-cap N  --port-file FILE
              --read-poll MS (idle read poll, default 500)
              --heartbeat MS (progress frame period, default 15000)
  client      one scripted request against a running daemon
              --addr HOST:PORT  then: ping|info|stats|metrics|history|shutdown
              dse|area|tune [dse axis flags]   pnr --app NAME   figure figN
              simulate --app NAME --fabric F --tokens N
              generate --width W --height H --tracks T --topology T --backend static|rv
              watch: stream timestamped history delta frames (NDJSON, one
               sample per line) until the daemon closes the connection
              --watch: print live progress frames (heartbeats carry jobs
               done/total, cache hits, coalesced joins, worker utilization);
               stats/metrics also print a latency-quantile + hit-rate summary
              --dash: terminal dashboard over `watch` — sparklines of request
               rate, latency p50, cache hit rate, plus live sweep + worker util
  info        version, compiled features, active placer backend, app registry
  help        this message

see docs/cli.md for the full reference, docs/dse.md for the DSE engine,
docs/service.md for the daemon protocol, and docs/observability.md for
spans, metrics, and trace files.";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    if cmd == "help" || args.has("help") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let result = match cmd {
        "generate" => cmd_generate(&args),
        "pnr" => cmd_pnr(&args),
        "bitstream" => cmd_bitstream(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "experiment" => cmd_experiment(&args),
        "dse" => cmd_dse(&args),
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
