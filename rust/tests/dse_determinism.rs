//! Determinism and cache semantics of the sharded DSE engine.
//!
//! Contract under test: engine results are bit-identical across worker
//! counts and cache temperature, the cache file round-trips losslessly,
//! and a warm re-run of the full figure suite (fig07/08/09/10/11/14/15)
//! performs zero PnR calls. The incremental-PnR flag adds two more:
//! `warm_start: false` is bit-identical to an engine that predates the
//! feature, and `warm_start: true` neighbor sweeps stay legal with
//! every critical path within 5% of the scratch result.

mod common;

use canal::coordinator::{self, ExpOptions};
use canal::dse::{DseEngine, EngineOptions, SweepSpec};
use canal::dsl::InterconnectConfig;
use canal::pnr::{BatchedNativePlacer, FlowParams, NativePlacer, SaParams};
use canal::sim::FabricKind;

use common::route_check::assert_routing_legal;

fn small_spec() -> SweepSpec {
    SweepSpec {
        name: "determinism".into(),
        base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
        tracks: vec![3, 4],
        apps: vec!["pointwise".into(), "gaussian".into()],
        seeds: vec![1, 2],
        flow: FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    }
}

fn run_with_workers(spec: &SweepSpec, workers: usize) -> canal::dse::SweepOutcome {
    let mut engine =
        DseEngine::new(EngineOptions { workers, cache_path: None, warm_start: false })
            .expect("engine");
    engine.run(spec, &NativePlacer::default()).expect("sweep")
}

#[test]
fn any_worker_count_is_bit_identical_to_sequential() {
    let spec = small_spec();
    let sequential = run_with_workers(&spec, 1);
    assert_eq!(sequential.points.len(), 8);
    for workers in [2, 4, 7] {
        let sharded = run_with_workers(&spec, workers);
        assert_eq!(sharded.points.len(), sequential.points.len(), "workers={workers}");
        for ((ja, ra), (jb, rb)) in sequential.points.iter().zip(&sharded.points) {
            assert_eq!(ja.key, jb.key, "workers={workers}");
            assert_eq!(ra, rb, "workers={workers} {:?}", ja.key);
            // f64 equality above is already exact; make bit-identity explicit.
            assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
            assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
        }
    }
}

#[test]
fn batched_placement_is_bit_identical_for_any_batch_size_and_worker_count() {
    // The PR-3 acceptance check: draining each per-config job group
    // through one batched solve must change nothing. The sequential
    // baseline is one worker with the scalar placer (the trait's default
    // place_batch loops optimize job-by-job); against it we vary both
    // the backend (vectorized BatchedNativePlacer) and the worker count
    // (which changes how groups shard and steal, i.e. the effective
    // batching pattern). Every point must be bit-identical, and the
    // placements behind them are pinned by the flow's determinism
    // (identical PointResults over f64-exact fields ⇒ identical
    // Placement, routing, and timing).
    let spec = small_spec();
    let sequential = {
        let mut e =
            DseEngine::new(EngineOptions { workers: 1, cache_path: None, warm_start: false })
                .unwrap();
        e.run(&spec, &NativePlacer::default()).unwrap()
    };
    assert_eq!(sequential.points.len(), 8);
    // 2 track configs x (2 apps x 2 seeds) ⇒ 2 groups of 4 problems.
    assert_eq!(sequential.stats.batched_solves, 2);
    for workers in [1, 2, 4, 7] {
        let batched = {
            let mut e =
                DseEngine::new(EngineOptions { workers, cache_path: None, warm_start: false })
                    .unwrap();
            e.run(&spec, &BatchedNativePlacer::default()).unwrap()
        };
        assert_eq!(batched.points.len(), sequential.points.len(), "workers={workers}");
        for ((ja, ra), (jb, rb)) in sequential.points.iter().zip(&batched.points) {
            // Same name ("native-gd") ⇒ same ConfigDescriptor ⇒ scalar
            // and batched runs share cache entries legitimately.
            assert_eq!(ja.key, jb.key, "workers={workers}");
            assert_eq!(ra, rb, "workers={workers} {:?}", ja.key);
            assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
            assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
        }
    }
}

#[test]
fn batched_and_sequential_flows_produce_identical_placements() {
    // Placement-level form of the batching contract: prepare a whole
    // group, solve it with one place_batch call, finish each point — the
    // resulting `Placement`s must equal the per-job run_flow_scratch
    // path exactly, for every batch size prefix.
    use canal::dsl::create_uniform_interconnect;
    use canal::pnr::{
        finish_flow_scratch, prepare_point, run_flow_scratch, GlobalPlacer, PlacementInstance,
        RouterScratch,
    };
    let ic = create_uniform_interconnect(&InterconnectConfig {
        mem_column_period: 3,
        ..Default::default()
    });
    let params = FlowParams {
        sa: SaParams { moves_per_node: 10, ..Default::default() },
        ..Default::default()
    };
    let apps = canal::apps::suite();
    let prepared: Vec<_> = apps.iter().map(|a| prepare_point(&ic, a, &params)).collect();
    let placer = BatchedNativePlacer::default();
    for batch_size in [1, 2, apps.len()] {
        for chunk_start in (0..apps.len()).step_by(batch_size) {
            let chunk = &prepared[chunk_start..(chunk_start + batch_size).min(prepared.len())];
            let batch: Vec<PlacementInstance> = chunk
                .iter()
                .map(|pp| PlacementInstance { problem: &pp.problem, xs0: &pp.xs0, ys0: &pp.ys0 })
                .collect();
            let solved = placer.place_batch(&batch);
            for (k, (pp, (xs, ys))) in chunk.iter().zip(&solved).enumerate() {
                let app = &apps[chunk_start + k];
                let batched =
                    finish_flow_scratch(&ic, pp, xs, ys, &params, &mut RouterScratch::new())
                        .unwrap_or_else(|e| panic!("{}: {e}", app.name));
                let sequential = run_flow_scratch(
                    &ic,
                    app,
                    &params,
                    &NativePlacer::default(),
                    &mut RouterScratch::new(),
                )
                .unwrap();
                assert_eq!(
                    batched.placement.pos, sequential.placement.pos,
                    "{} batch_size={batch_size}",
                    app.name
                );
                assert_eq!(
                    batched.timing.critical_path_ps.to_bits(),
                    sequential.timing.critical_path_ps.to_bits()
                );
                // Both paths must also produce *legal* routing — the
                // shared suite checks disjointness, tree connectivity,
                // and fan-in-order mux selects.
                let nets = batched.packed.app.nets().len();
                assert_routing_legal(&ic, 16, &batched.routing, nets, &app.name);
                assert_routing_legal(&ic, 16, &sequential.routing, nets, &app.name);
            }
        }
    }
}

fn fabric_spec() -> SweepSpec {
    SweepSpec {
        name: "fabric-determinism".into(),
        tracks: vec![4],
        fabrics: vec![
            FabricKind::Static,
            FabricKind::RvFullFifo { depth: 2 },
            FabricKind::RvSplitFifo,
        ],
        ..small_spec()
    }
}

#[test]
fn fabric_axis_sweeps_are_bit_identical_sharded_vs_sequential() {
    // The fabric axis rides the same determinism contract as every
    // other axis: the elastic simulation is a pure function of the
    // routed flow and the fabric, so worker count changes nothing.
    let spec = fabric_spec();
    let sequential = run_with_workers(&spec, 1);
    // 1 track × 3 fabrics × 2 apps × 2 seeds.
    assert_eq!(sequential.points.len(), 12);
    let routed = sequential.points.iter().filter(|(_, r)| r.routed).count() as u64;
    assert!(routed > 0, "spec produced no routable points");
    assert_eq!(sequential.stats.sims, routed, "every routed cold point simulates");
    for workers in [2, 4, 7] {
        let sharded = run_with_workers(&spec, workers);
        assert_eq!(sharded.points.len(), sequential.points.len(), "workers={workers}");
        for ((ja, ra), (jb, rb)) in sequential.points.iter().zip(&sharded.points) {
            assert_eq!(ja.key, jb.key, "workers={workers}");
            assert_eq!(ra, rb, "workers={workers} {:?}", ja.key);
            assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
            assert_eq!(
                (ra.sim_cycles, ra.sim_tokens, ra.stall_cycles),
                (rb.sim_cycles, rb.sim_tokens, rb.stall_cycles),
                "workers={workers} {:?}",
                ja.key
            );
        }
    }
}

#[test]
fn fabric_axis_warm_rerun_does_zero_pnr_and_zero_sims() {
    // File-backed acceptance check: a warm re-run of a fabric sweep
    // performs zero PnR calls AND zero simulations, and the cache file
    // keys fabric rows distinctly (static rows stay bare).
    let path = std::env::temp_dir()
        .join(format!("canal_dse_fabric_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let spec = fabric_spec();

    let cold = {
        let mut engine =
            DseEngine::new(EngineOptions {
                workers: 3,
                cache_path: Some(path.clone()),
                warm_start: false,
            })
            .expect("engine");
        engine.run(&spec, &NativePlacer::default()).expect("cold sweep")
    };
    assert_eq!(cold.stats.pnr_runs, 12);
    let routed = cold.points.iter().filter(|(_, r)| r.routed).count() as u64;
    assert!(routed > 0, "spec produced no routable points");
    assert_eq!(cold.stats.sims, routed);

    let text = std::fs::read_to_string(&path).expect("cache file written");
    assert!(text.contains("fabric=rv-full:2"), "full-FIFO rows must be keyed distinctly");
    assert!(text.contains("fabric=rv-split"), "split-FIFO rows must be keyed distinctly");

    let warm = {
        let mut engine =
            DseEngine::new(EngineOptions {
                workers: 3,
                cache_path: Some(path.clone()),
                warm_start: false,
            })
            .expect("engine");
        engine.run(&spec, &NativePlacer::default()).expect("warm sweep")
    };
    std::fs::remove_file(&path).expect("cache file removed");
    assert_eq!(warm.stats.pnr_runs, 0, "warm re-run must skip all PnR");
    assert_eq!(warm.stats.sims, 0, "warm re-run must skip all simulations");
    assert_eq!(warm.stats.cache_hits, 12);
    for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
        assert_eq!(ja.key, jb.key);
        assert_eq!(ra, rb);
    }
}

#[test]
fn warm_cache_is_bit_identical_and_file_backed() {
    let path = std::env::temp_dir()
        .join(format!("canal_dse_determinism_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let spec = small_spec();

    let cold = {
        let mut engine =
            DseEngine::new(EngineOptions {
                workers: 3,
                cache_path: Some(path.clone()),
                warm_start: false,
            })
            .expect("engine");
        engine.run(&spec, &NativePlacer::default()).expect("cold sweep")
    };
    assert_eq!(cold.stats.pnr_runs, cold.points.len() as u64);
    assert_eq!(cold.stats.cache_hits, 0);

    // A *new* engine over the same cache file: every point must come from
    // disk, bit-identical.
    let warm = {
        let mut engine =
            DseEngine::new(EngineOptions {
                workers: 3,
                cache_path: Some(path.clone()),
                warm_start: false,
            })
            .expect("engine");
        engine.run(&spec, &NativePlacer::default()).expect("warm sweep")
    };
    std::fs::remove_file(&path).expect("cache file written");
    assert_eq!(warm.stats.pnr_runs, 0, "warm re-run must skip all PnR");
    assert_eq!(warm.stats.cache_hits, cold.points.len() as u64);
    assert_eq!(warm.stats.configs_built, 0);
    for ((ja, ra), (jb, rb)) in cold.points.iter().zip(&warm.points) {
        assert_eq!(ja.key, jb.key);
        assert_eq!(ra, rb);
        assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
    }
}

#[test]
fn warm_start_off_is_bit_identical_to_default_engine() {
    // The incremental-PnR flag-off contract: an engine constructed with
    // an explicit `warm_start: false` is byte-for-byte the engine that
    // predates the feature — same points (f64-exact), same stats (zero
    // warm counters), same serialized cache.
    let spec = small_spec();
    let mut default_engine = DseEngine::in_memory();
    let baseline = default_engine.run(&spec, &NativePlacer::default()).expect("baseline");
    let mut flag_off =
        DseEngine::new(EngineOptions { workers: 3, cache_path: None, warm_start: false })
            .expect("engine");
    let off = flag_off.run(&spec, &NativePlacer::default()).expect("flag-off sweep");
    assert!(flag_off.artifacts().is_none(), "flag-off engines carry no artifact store");
    assert_eq!(off.stats.warm_starts, 0);
    assert_eq!(off.stats.nets_reused, 0);
    assert_eq!(off.stats.nets_rerouted, 0);
    assert_eq!(off.points.len(), baseline.points.len());
    for ((ja, ra), (jb, rb)) in baseline.points.iter().zip(&off.points) {
        assert_eq!(ja.key, jb.key);
        assert_eq!(ra, rb, "{:?}", ja.key);
        assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
        assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
    }
    assert_eq!(
        default_engine.cache().to_json(),
        flag_off.cache().to_json(),
        "flag-off cache serialization must be byte-identical"
    );
}

#[test]
fn warm_start_neighbor_sweep_reuses_trees_and_stays_within_5_percent() {
    // The incremental-PnR flag-on acceptance: sweep a tracks × fabric
    // neighborhood with warm starts on (artifact store file-backed) —
    // neighbors must actually warm-start and replay donor trees, every
    // warm point must still route, and no critical path may degrade
    // more than 5% against the scratch engine's result for the same key.
    let path = std::env::temp_dir()
        .join(format!("canal_dse_warm_start_{}.json", std::process::id()));
    let artifacts = canal::dse::artifact_path_for(&path);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&artifacts);
    let spec = SweepSpec {
        name: "warm-neighbors".into(),
        tracks: vec![3, 4],
        fabrics: vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 2 }],
        apps: vec!["pointwise".into()],
        seeds: vec![1],
        ..small_spec()
    };
    let mut scratch_engine = DseEngine::in_memory();
    let scratch = scratch_engine.run(&spec, &NativePlacer::default()).expect("scratch sweep");

    let warm = {
        let mut engine = DseEngine::new(EngineOptions {
            workers: 1,
            cache_path: Some(path.clone()),
            warm_start: true,
        })
        .expect("engine");
        engine.run(&spec, &NativePlacer::default()).expect("warm sweep")
    };
    let artifact_text = std::fs::read_to_string(&artifacts).expect("artifact store persisted");
    std::fs::remove_file(&path).expect("cache file written");
    std::fs::remove_file(&artifacts).expect("artifact file written");
    assert!(artifact_text.contains("\"version\""), "artifact store must be versioned");

    assert!(warm.stats.warm_starts > 0, "neighbors must warm-start: {:?}", warm.stats);
    assert!(
        warm.stats.nets_reused > 0,
        "the fabric twin is the same PnR problem — trees must replay: {:?}",
        warm.stats
    );
    assert_eq!(warm.points.len(), scratch.points.len());
    for ((ja, ra), (jb, rb)) in scratch.points.iter().zip(&warm.points) {
        assert_eq!(ja.key, jb.key, "warm-start must not reorder the outcome");
        assert!(rb.routed, "warm point must stay routable: {:?}", jb.key);
        assert!(
            rb.critical_path_ps <= ra.critical_path_ps * 1.05,
            "{:?}: warm {} vs scratch {} exceeds the 5% bar",
            jb.key,
            rb.critical_path_ps,
            ra.critical_path_ps
        );
    }
}

#[test]
fn trace_on_is_bit_identical_to_trace_off() {
    // The observability zero-feedback contract: opening the gate fully
    // (spans + metrics recording on every pack/place/route/sta/sim
    // stage and engine event) changes no result bit. Recording is
    // write-only — nothing in the flow ever reads a metric or span —
    // so this holds by construction; the test pins it against
    // regression. The gate is process-global: concurrent tests in this
    // binary may record spans during the `full()` window, which is
    // harmless precisely because of the contract under test.
    use canal::obs::ObsOptions;
    let spec = fabric_spec();
    ObsOptions::disabled().apply();
    let off = run_with_workers(&spec, 3);
    ObsOptions::full().apply();
    let on = run_with_workers(&spec, 3);
    ObsOptions::disabled().apply();
    assert_eq!(on.points.len(), off.points.len());
    for ((ja, ra), (jb, rb)) in off.points.iter().zip(&on.points) {
        assert_eq!(ja.key, jb.key);
        assert_eq!(ra, rb, "traced run diverged at {:?}", ja.key);
        assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
        assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
        assert_eq!(
            (ra.sim_cycles, ra.sim_tokens, ra.stall_cycles),
            (rb.sim_cycles, rb.sim_tokens, rb.stall_cycles)
        );
    }
    assert_eq!(on.stats.pnr_runs, off.stats.pnr_runs);
    assert_eq!(on.stats.batched_solves, off.stats.batched_solves);
}

#[test]
fn figure_suite_warm_rerun_does_zero_pnr() {
    // The acceptance check for the engine port: render fig07-15
    // through one shared engine, then render them all again — the second
    // pass must hit the cache for every point (zero PnR runs) and produce
    // byte-identical tables.
    let o = ExpOptions { sa_moves: 2, seeds: 1, ..Default::default() };
    let placer = NativePlacer::default();
    let mut engine = DseEngine::in_memory();

    let render_all = |engine: &mut DseEngine| -> String {
        let mut s = String::new();
        s.push_str(&coordinator::fig07_hybrid_throughput_with(&o, &placer, engine).render());
        s.push_str(&coordinator::fig08_fifo_area_with(engine).render());
        s.push_str(&coordinator::fig09_topology_with(&o, engine).render());
        s.push_str(&coordinator::fig10_area_tracks_with(engine).render());
        s.push_str(&coordinator::fig11_runtime_tracks_with(&o, &placer, engine).render());
        s.push_str(&coordinator::fig14_sb_ports_runtime_with(&o, &placer, engine).render());
        s.push_str(&coordinator::fig15_cb_ports_runtime_with(&o, &placer, engine).render());
        s
    };

    let cold_tables = render_all(&mut engine);
    let cold_runs = engine.lifetime_stats().pnr_runs;
    let cold_sims = engine.lifetime_stats().sims;
    assert!(cold_runs > 0, "cold figure pass must perform PnR");
    assert!(cold_sims > 0, "cold figure pass must simulate");

    let warm_tables = render_all(&mut engine);
    let warm_runs = engine.lifetime_stats().pnr_runs - cold_runs;
    let warm_sims = engine.lifetime_stats().sims - cold_sims;
    assert_eq!(warm_runs, 0, "warm figure re-run must perform zero PnR calls");
    assert_eq!(warm_sims, 0, "warm figure re-run must perform zero simulations");
    assert_eq!(cold_tables, warm_tables, "warm tables must be byte-identical");
}
