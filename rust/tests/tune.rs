//! Acceptance tests for the `canal tune` Pareto autotuner.
//!
//! Contracts under test: the tuned search recovers exactly the Pareto
//! frontier an exhaustive `canal dse` enumeration yields, with strictly
//! fewer cold PnR evaluations than the cross-product; the persisted
//! archive is bit-identical across worker counts; a warm re-tune
//! performs zero PnR and zero sims; and NaN-metric cache entries (the
//! JSON `null` round trip of unroutable or legacy points) classify as
//! unroutable instead of poisoning dominance ordering or table output.

use canal::area::{area_of, AreaModel};
use canal::dse::{
    archive_path_for, dominates, objectives_of, pareto_frontier, points_table, run_tune,
    DseEngine, EngineOptions, Objectives, ParetoArchive, ParetoEntry, PointResult, ResultCache,
    SweepOutcome, SweepSpec, TuneOptions, TuneOutcome,
};
use canal::dsl::{create_uniform_interconnect, InterconnectConfig};
use canal::pnr::{FlowParams, GlobalPlacer, NativePlacer, SaParams};

/// The search space every test tunes: a tracks-only axis on a tiny 4x4
/// static array. Area strictly increases with tracks while the routed
/// period and simulated throughput do not improve, so the higher-track
/// candidates are strictly dominated after the first seed round — the
/// successive-halving drop must fire, which is what makes
/// `evaluated < cross_product` achievable at all.
fn tune_spec(name: &str, tracks: Vec<u16>) -> SweepSpec {
    SweepSpec {
        name: name.into(),
        base: InterconnectConfig {
            width: 4,
            height: 4,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks,
        apps: vec!["pointwise4".into()],
        seeds: vec![1, 2],
        flow: FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Exhaustive reference: fold a full enumerating sweep into
/// per-(config, app) aggregates — same area model, same objective
/// extraction as the tuner — and filter to the Pareto frontier.
fn exhaustive_frontier(out: &SweepOutcome) -> Vec<ParetoEntry> {
    let model = AreaModel::default();
    let mut areas: std::collections::HashMap<String, f64> = std::collections::HashMap::new();
    let mut agg: std::collections::BTreeMap<(String, String), ParetoEntry> =
        std::collections::BTreeMap::new();
    for (job, r) in &out.points {
        let area = *areas.entry(job.key.config.0.clone()).or_insert_with(|| {
            let ic = create_uniform_interconnect(&job.cfg);
            area_of(&ic, &model, job.fabric.area_mode()).interior_tile(&ic).total()
        });
        let o = objectives_of(r, area);
        let key = (job.key.config.0.clone(), job.key.app.clone());
        match agg.get_mut(&key) {
            Some(e) => {
                e.objectives.fold(&o);
                if let Err(at) = e.seeds.binary_search(&job.key.seed) {
                    e.seeds.insert(at, job.key.seed);
                }
            }
            None => {
                agg.insert(
                    key,
                    ParetoEntry {
                        config: job.key.config.0.clone(),
                        app: job.key.app.clone(),
                        fabric: job.fabric.label(),
                        objectives: o,
                        seeds: vec![job.key.seed],
                    },
                );
            }
        }
    }
    let entries: Vec<ParetoEntry> =
        agg.into_values().filter(|e| e.objectives.is_finite()).collect();
    pareto_frontier(&entries)
}

fn run_tune_with_workers(
    spec: &SweepSpec,
    workers: usize,
    archive: &mut ParetoArchive,
) -> TuneOutcome {
    let mut engine =
        DseEngine::new(EngineOptions { workers, cache_path: None, warm_start: false })
            .expect("engine");
    let placer = NativePlacer::default();
    run_tune(spec, placer.name(), &canal::dse::BuildFresh, archive, &TuneOptions::default(), &mut |s| {
        engine.run(s, &placer)
    })
    .expect("tune")
}

#[test]
fn tuned_search_recovers_the_exhaustive_frontier_with_fewer_evaluations() {
    // The headline acceptance criterion: exact frontier, strictly fewer
    // cold PnR evaluations than the 3 tracks × 1 app × 2 seeds = 6-job
    // cross-product.
    let spec = tune_spec("tune-acceptance", vec![2, 3, 4]);
    let mut archive = ParetoArchive::in_memory();
    let tuned = run_tune_with_workers(&spec, 2, &mut archive);
    assert_eq!(tuned.cross_product, 6);
    assert!(
        tuned.evaluated < tuned.cross_product,
        "search must beat enumeration: {} evaluations vs {} cross-product",
        tuned.evaluated,
        tuned.cross_product
    );
    assert!(
        tuned.stats.pnr_runs < tuned.cross_product,
        "cold search must run strictly fewer PnR calls than the cross-product \
         ({} vs {})",
        tuned.stats.pnr_runs,
        tuned.cross_product
    );
    assert!(tuned.dropped > 0, "the halving drop must fire on this space");
    assert!(!tuned.frontier.is_empty());

    let mut engine = DseEngine::in_memory();
    let full = engine.run(&spec, &NativePlacer::default()).expect("exhaustive sweep");
    assert_eq!(full.points.len(), 6);
    let reference = exhaustive_frontier(&full);
    assert_eq!(
        tuned.frontier, reference,
        "tuned frontier must equal the exhaustive sweep's frontier exactly"
    );
    // Frontier objectives are bit-exact against the reference, not just
    // PartialEq-equal.
    for (t, r) in tuned.frontier.iter().zip(&reference) {
        assert_eq!(t.objectives.area_um2.to_bits(), r.objectives.area_um2.to_bits());
        assert_eq!(t.objectives.period_ps.to_bits(), r.objectives.period_ps.to_bits());
        assert_eq!(t.objectives.throughput.to_bits(), r.objectives.throughput.to_bits());
    }
}

#[test]
fn archive_bytes_are_identical_across_worker_counts() {
    // Determinism contract: candidates, rounds, and merges iterate
    // BTree-ordered state in canonical spec order, so for a fixed cache
    // temperature the archive serialization is a pure function of the
    // spec — any worker count, same bytes.
    let spec = tune_spec("tune-workers", vec![2, 3, 4]);
    let baseline = {
        let mut archive = ParetoArchive::in_memory();
        run_tune_with_workers(&spec, 1, &mut archive);
        archive.to_json()
    };
    assert!(baseline.contains("\"version\""), "archive must be versioned");
    for workers in [2, 4, 7] {
        let sharded = {
            let mut archive = ParetoArchive::in_memory();
            run_tune_with_workers(&spec, workers, &mut archive);
            archive.to_json()
        };
        assert_eq!(baseline, sharded, "archive bytes diverged at workers={workers}");
    }
}

#[test]
fn warm_retune_runs_zero_pnr_and_zero_sims_through_the_files() {
    // Persistence end-to-end: a fresh engine + freshly loaded archive
    // over the same backing files must answer every evaluation from the
    // result cache and reproduce the same frontier.
    let cache = std::env::temp_dir()
        .join(format!("canal_tune_warm_{}.json", std::process::id()));
    let archive_file = archive_path_for(&cache);
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&archive_file);
    let spec = tune_spec("tune-warm", vec![2, 3]);
    let placer = NativePlacer::default();
    let pass = || -> TuneOutcome {
        let mut engine = DseEngine::new(EngineOptions {
            workers: 2,
            cache_path: Some(cache.clone()),
            warm_start: false,
        })
        .expect("engine");
        let mut archive = ParetoArchive::at(&archive_file).expect("archive");
        run_tune(
            &spec,
            placer.name(),
            &canal::dse::BuildFresh,
            &mut archive,
            &TuneOptions::default(),
            &mut |s| engine.run(s, &placer),
        )
        .expect("tune")
    };
    let cold = pass();
    let archive_bytes = std::fs::read_to_string(&archive_file).expect("archive persisted");
    let warm = pass();
    let warm_bytes = std::fs::read_to_string(&archive_file).expect("archive persisted");
    let _ = std::fs::remove_file(&cache);
    let _ = std::fs::remove_file(&archive_file);
    assert!(cold.stats.pnr_runs > 0, "cold tune must run real PnR");
    assert_eq!(warm.stats.pnr_runs, 0, "warm re-tune must skip all PnR");
    assert_eq!(warm.stats.sims, 0, "warm re-tune must skip all simulations");
    assert!(warm.stats.cache_hits > 0);
    assert_eq!(warm.frontier, cold.frontier);
    assert_eq!(archive_bytes, warm_bytes, "a warm re-tune must not change the archive");
}

#[test]
fn nan_metrics_in_a_warm_cache_never_poison_the_search() {
    // The NaN-ordering regression: `Json::num_f64` persists non-finite
    // metrics as `null` and the cache decoder reads them back as NaN, so
    // a warm cache can serve a "routed" point whose runtime/period are
    // NaN. The tuner must classify it as unroutable (it never enters the
    // archive, never dominates anything) and the report table must
    // render dashes, not "NaN".
    let spec = tune_spec("tune-nan", vec![2, 3]);
    let placer = NativePlacer::default();
    let jobs = spec.jobs(placer.name()).expect("jobs");
    assert_eq!(jobs.len(), 4);
    // Poison every seed of the lowest-track config — the candidate that
    // would otherwise win on area.
    let poisoned: Vec<_> =
        jobs.iter().filter(|j| j.cfg.num_tracks == 2).map(|j| j.key.clone()).collect();
    assert_eq!(poisoned.len(), 2);
    let nan_point = PointResult {
        routed: true,
        critical_path_ps: f64::NAN,
        period_ps: f64::NAN,
        runtime_ns: f64::NAN,
        alpha: f64::NAN,
        ..PointResult::unroutable()
    };
    let mut cache = ResultCache::in_memory();
    for key in &poisoned {
        cache.insert(key.clone(), nan_point.clone());
    }
    let mut engine = DseEngine::with_cache(
        EngineOptions { workers: 2, cache_path: None, warm_start: false },
        cache,
    );
    let mut archive = ParetoArchive::in_memory();
    let tuned = run_tune(
        &spec,
        placer.name(),
        &canal::dse::BuildFresh,
        &mut archive,
        &TuneOptions::default(),
        &mut |s| engine.run(s, &placer),
    )
    .expect("tune must survive NaN cache entries");
    assert!(!tuned.frontier.is_empty(), "the healthy candidate must make the frontier");
    let poisoned_config = &poisoned[0].config.0;
    for e in &tuned.frontier {
        assert_ne!(
            &e.config, poisoned_config,
            "a NaN-metric candidate must never enter the frontier"
        );
        assert!(e.objectives.is_finite());
    }
    // And the rendered sweep table shows the NaN point as data-less.
    let out = engine.run(&spec, &placer).expect("sweep over the poisoned cache");
    let rendered = points_table(&out).render();
    assert!(
        !rendered.contains("NaN"),
        "points table must render NaN metrics as dashes:\n{rendered}"
    );
}

#[test]
fn dominance_is_strict_antisymmetric_and_nan_safe() {
    // Property sweep over a small objective grid (finite values and
    // NaN): dominance is irreflexive, antisymmetric, and NaN never
    // dominates while any finite point dominates a NaN one.
    let vals = [1.0, 2.0, f64::NAN];
    let mut points = Vec::new();
    for &a in &vals {
        for &p in &vals {
            for &t in &vals {
                points.push(Objectives { area_um2: a, period_ps: p, throughput: t });
            }
        }
    }
    for x in &points {
        assert!(!dominates(x, x), "irreflexive: {x:?}");
        for y in &points {
            assert!(
                !(dominates(x, y) && dominates(y, x)),
                "antisymmetric: {x:?} vs {y:?}"
            );
            if !x.is_finite() {
                assert!(!dominates(x, y), "NaN never dominates: {x:?} vs {y:?}");
            }
            if x.is_finite() && !y.is_finite() {
                assert!(dominates(x, y), "finite beats NaN: {x:?} vs {y:?}");
            }
        }
    }
}
