//! Integration tests: the full generate → PnR → bitstream → simulate
//! pipeline across interconnect variants (Fig. 2 end to end).

use canal::apps;
use canal::bitstream::{decode, encode, Configuration};
use canal::dsl::{create_uniform_interconnect, ConnectedSides, InterconnectConfig, SbTopology};
use canal::hw::{allocate, emit, lower_ready_valid, lower_static, verify_rtl, RvOptions};
use canal::pnr::{run_flow, FlowParams, SaParams};
use canal::sim::{check_routing, sweep_connections};

fn quick_params() -> FlowParams {
    FlowParams { sa: SaParams { moves_per_node: 6, ..Default::default() }, ..Default::default() }
}

/// Full pipeline on the paper baseline for every suite app.
#[test]
fn pipeline_suite_on_baseline() {
    let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
    let cs = allocate(&ic);
    for app in apps::suite() {
        let r = run_flow(&ic, &app, &quick_params())
            .unwrap_or_else(|e| panic!("{}: {e}", app.name));
        let cfg = Configuration::from_routing(&ic, 16, &r.routing)
            .unwrap_or_else(|e| panic!("{}: {e}", app.name));
        // encode -> decode -> simulate: the delivered configuration (not
        // just the abstract one) must deliver every net.
        let bits = encode(&cfg, &cs);
        let decoded = decode(&bits, &cs);
        check_routing(&ic, 16, &decoded, &r.routing)
            .unwrap_or_else(|e| panic!("{}: decoded bitstream broken: {e}", app.name));
    }
}

/// Every interconnect variant used in the DSE experiments generates
/// verifiable hardware and passes the exhaustive connection sweep.
#[test]
fn generate_verify_sweep_across_variants() {
    let variants = [
        InterconnectConfig { num_tracks: 2, ..InterconnectConfig::paper_baseline(4, 4) },
        InterconnectConfig {
            sb_topology: SbTopology::Disjoint,
            ..InterconnectConfig::paper_baseline(4, 4)
        },
        InterconnectConfig {
            sb_core_sides: ConnectedSides::TWO,
            cb_core_sides: ConnectedSides::THREE,
            ..InterconnectConfig::paper_baseline(4, 4)
        },
        InterconnectConfig {
            track_widths: vec![1, 16],
            reg_density: 2,
            ..InterconnectConfig::paper_baseline(4, 4)
        },
    ];
    for cfg in variants {
        let ic = create_uniform_interconnect(&cfg);
        let rtl = emit(&lower_static(&ic).netlist);
        let mismatches = verify_rtl(&ic, &rtl);
        assert!(mismatches.is_empty(), "{}: {:?}", cfg.descriptor(), &mismatches[..mismatches.len().min(3)]);
        let cs = allocate(&ic);
        let sweep = sweep_connections(&ic, Some(&cs));
        assert!(sweep.ok(), "{}: {:?}", cfg.descriptor(), &sweep.failures[..sweep.failures.len().min(3)]);
    }
}

/// Ready-valid generation verifies for the same variants.
#[test]
fn rv_generation_across_variants() {
    for (split, depth) in [(true, 2), (false, 2), (false, 4)] {
        let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(4, 4));
        let lowered = lower_ready_valid(&ic, &RvOptions { fifo_depth: depth, split });
        let rtl = emit(&lowered.netlist);
        let mismatches = verify_rtl(&ic, &rtl);
        assert!(mismatches.is_empty(), "split={split} depth={depth}");
        // One FIFO per register node.
        let regs: usize = ic
            .graphs
            .values()
            .map(|g| g.iter().filter(|(_, n)| n.kind.is_register()).count())
            .sum();
        assert_eq!(lowered.netlist.histogram()["fifo"], regs);
    }
}

/// Routing respects per-app determinism across repeated full flows.
#[test]
fn flow_reproducible_across_processes() {
    let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
    let app = apps::harris();
    let a = run_flow(&ic, &app, &quick_params()).unwrap();
    let b = run_flow(&ic, &app, &quick_params()).unwrap();
    assert_eq!(a.placement.pos, b.placement.pos);
    assert_eq!(a.routing.nodes_used, b.routing.nodes_used);
    assert_eq!(a.timing.critical_path_ps, b.timing.critical_path_ps);
}

/// Larger array: the 16x16 baseline routes the whole suite (the array
/// the paper's Fig. 4 example parameterizes is 32x32; 16x16 keeps CI
/// fast while exercising multi-hop routes).
#[test]
fn suite_routes_on_16x16() {
    let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(16, 16));
    for app in apps::suite() {
        let r = run_flow(&ic, &app, &quick_params())
            .unwrap_or_else(|e| panic!("{}: {e}", app.name));
        assert!(r.timing.critical_path_ps > 0.0);
    }
}

/// Registered fabrics (reg_density 1 and 2) still route and verify.
#[test]
fn registered_fabrics_route() {
    for density in [1u16, 2] {
        let cfg = InterconnectConfig { reg_density: density, ..InterconnectConfig::paper_baseline(8, 8) };
        let ic = create_uniform_interconnect(&cfg);
        let r = run_flow(&ic, &apps::gaussian(), &quick_params())
            .unwrap_or_else(|e| panic!("density {density}: {e}"));
        let cfg2 = Configuration::from_routing(&ic, 16, &r.routing).unwrap();
        check_routing(&ic, 16, &cfg2, &r.routing).unwrap();
    }
}
