//! Shared helpers for the integration-test suite. Each test binary that
//! wants them declares `mod common;` — the directory is not itself a
//! test crate, so the helpers compile once per consumer and nothing
//! here runs as a test.

pub mod route_check;
