//! The one route-legality checker every routing test shares.
//!
//! Before this module, warm_start.rs, prop_invariants.rs, and
//! dse_determinism.rs each carried their own partial copy of "is this
//! routing legal" — node-disjointness here, edge-existence there, the
//! fan-in-order mux-select invariant only in the e2e bitstream test.
//! [`assert_routing_legal`] is the union of all of them, so every
//! consumer checks every invariant for free:
//!
//! 1. every net routed, every sink reached (one path per sink);
//! 2. each tree is a connected subtree containing the source: all of a
//!    net's paths start at one source node, and no node in the tree has
//!    two different drivers (the Steiner-sharing invariant);
//! 3. every path step is a real edge of the routing graph;
//! 4. no routing-graph node serves two different nets (capacity 1);
//! 5. fan-in-order mux-select encoding (the PR 1 invariant): for every
//!    multi-input node a route drives, `select_of` names an index whose
//!    fan-in entry is exactly the driving node, and the bitstream
//!    `Configuration` built from the routing encodes that same index.

use std::collections::HashMap;

use canal::bitstream::Configuration;
use canal::ir::{Interconnect, NodeId};
use canal::pnr::RoutingResult;

/// Assert every routing invariant the suite knows about. `expect_nets`
/// is the net count of the packed app (every net must have routed);
/// `ctx` prefixes panic messages so property tests can report their
/// case/seed.
pub fn assert_routing_legal(
    ic: &Interconnect,
    bit_width: u8,
    routing: &RoutingResult,
    expect_nets: usize,
    ctx: &str,
) {
    let g = ic.graph(bit_width);
    assert_eq!(routing.trees.len(), expect_nets, "{ctx}: not every net routed");

    // Cross-net capacity: each node belongs to at most one net.
    let mut owner: HashMap<NodeId, usize> = HashMap::new();
    // Within-net driver: each node is entered from at most one
    // predecessor (a tree, not a DAG).
    let mut driver: HashMap<NodeId, NodeId> = HashMap::new();

    for (ni, tree) in routing.trees.iter().enumerate() {
        assert!(!tree.sink_paths.is_empty(), "{ctx}: net {ni} has no paths");
        assert_eq!(
            tree.sink_paths.len(),
            tree.net.sinks.len(),
            "{ctx}: net {ni} missed a sink"
        );
        let src = tree.sink_paths[0][0];
        driver.clear();
        for (si, path) in tree.sink_paths.iter().enumerate() {
            assert!(path.len() >= 2, "{ctx}: net {ni} sink {si}: degenerate path");
            assert_eq!(
                path[0], src,
                "{ctx}: net {ni} sink {si} does not start at the net source"
            );
            for w in path.windows(2) {
                let (a, b) = (w[0], w[1]);
                assert!(
                    g.fan_out(a).contains(&b),
                    "{ctx}: net {ni} sink {si}: {a:?} -> {b:?} is not an edge"
                );
                match driver.get(&b) {
                    Some(&prev) => assert_eq!(
                        prev, a,
                        "{ctx}: net {ni}: node {b:?} driven from two predecessors"
                    ),
                    None => {
                        driver.insert(b, a);
                    }
                }
            }
        }
        for n in tree.nodes() {
            match owner.get(&n) {
                Some(&other) => {
                    panic!("{ctx}: node {n:?} shared by nets {other} and {ni}")
                }
                None => {
                    owner.insert(n, ni);
                }
            }
        }
    }

    // Fan-in-order mux-select encoding, checked two ways: the builder
    // graph's select index must point back at the driving edge, and the
    // bitstream configuration built from this routing must encode
    // exactly that index for every driven mux.
    let config = Configuration::from_routing(ic, bit_width, routing)
        .unwrap_or_else(|e| panic!("{ctx}: configuration rejected legal routing: {e}"));
    for tree in &routing.trees {
        for path in &tree.sink_paths {
            for w in path.windows(2) {
                let (a, b) = (w[0], w[1]);
                if g.fan_in(b).len() > 1 {
                    let sel = g
                        .select_of(b, a)
                        .unwrap_or_else(|| panic!("{ctx}: no select for {a:?} -> {b:?}"));
                    assert_eq!(
                        g.fan_in(b)[sel],
                        a,
                        "{ctx}: select {sel} of {b:?} is not fan-in-ordered"
                    );
                    assert_eq!(
                        config.selects.get(&(bit_width, b)),
                        Some(&(sel as u32)),
                        "{ctx}: bitstream select for {b:?} disagrees with fan-in order"
                    );
                }
            }
        }
    }
}
