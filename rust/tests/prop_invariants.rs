//! Property-based tests over coordinator invariants.
//!
//! proptest is unavailable offline, so these use the crate's
//! deterministic RNG to generate hundreds of random cases per property
//! (with printed seeds for reproduction) — same discipline: random
//! structure in, invariant checked, seed reported on failure.

mod common;

use canal::bitstream::{decode, encode, Configuration};
use canal::dsl::{create_uniform_interconnect, ConnectedSides, InterconnectConfig, SbTopology};
use canal::hw::allocate;
use canal::ir::validate;
use canal::pnr::{
    detailed_place, legalize, pack, route, AppGraph, AppOp, Placement, RouterParams, SaParams,
};
use canal::util::rng::Rng;

use common::route_check::assert_routing_legal;

/// Random interconnect config within the supported envelope.
fn random_config(rng: &mut Rng) -> InterconnectConfig {
    InterconnectConfig {
        width: 3 + rng.below(4) as u16,
        height: 3 + rng.below(4) as u16,
        num_tracks: 1 + rng.below(5) as u16,
        track_widths: if rng.below(3) == 0 { vec![1, 16] } else { vec![16] },
        sb_topology: [SbTopology::Wilton, SbTopology::Disjoint, SbTopology::Imran]
            [rng.below(3)],
        reg_density: rng.below(3) as u16,
        sb_core_sides: ConnectedSides(2 + rng.below(3) as u8),
        cb_core_sides: ConnectedSides(2 + rng.below(3) as u8),
        mem_column_period: [0u16, 2, 3][rng.below(3)],
        ..Default::default()
    }
}

/// Random layered DAG application that fits a small array.
fn random_app(rng: &mut Rng, max_nodes: usize) -> AppGraph {
    let mut g = AppGraph::new("random");
    let n_in = 1 + rng.below(2);
    let mut prev: Vec<_> = (0..n_in).map(|i| g.mem(&format!("in{i}"), "stream_in")).collect();
    let mut total = n_in;
    let mut first_layer = true;
    while total < max_nodes - 2 {
        // The first layer covers every input round-robin so no stream-in
        // vertex is left disconnected.
        let layer = if first_layer {
            n_in.max(1 + rng.below(3.min(max_nodes - total)))
        } else {
            1 + rng.below(3.min(max_nodes - total))
        };
        let mut next = Vec::new();
        for i in 0..layer {
            let op = ["add", "mul", "sub", "max"][rng.below(4)];
            let v = g.alu(&format!("op{total}_{i}"), op);
            let src = if first_layer { prev[i % prev.len()] } else { prev[rng.below(prev.len())] };
            g.connect(src, 0, v, 0);
            if rng.below(2) == 0 && prev.len() > 1 {
                g.connect(prev[rng.below(prev.len())], 0, v, 1);
            } else {
                let k = g.add(&format!("k{total}_{i}"), AppOp::Const(rng.below(100) as i64));
                g.connect(k, 0, v, 1);
            }
            next.push(v);
            total += 1;
        }
        prev = next;
        first_layer = false;
    }
    let out = g.mem("out", "stream_out");
    g.wire(prev[0], out, 0);
    g
}

/// Property: every generated uniform interconnect is a valid IR.
#[test]
fn prop_generated_interconnects_valid() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..60 {
        let cfg = random_config(&mut rng);
        let ic = create_uniform_interconnect(&cfg);
        let v = validate(&ic);
        assert!(v.is_empty(), "case {case} ({}): {:?}", cfg.descriptor(), &v[..v.len().min(3)]);
    }
}

/// Property: packing never invents or loses connectivity — every non-const
/// source vertex that survives still reaches the same consumers.
#[test]
fn prop_packing_preserves_reachability() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..80 {
        let max_nodes = 6 + rng.below(20);
        let app = random_app(&mut rng, max_nodes);
        app.check().unwrap_or_else(|e| panic!("case {case}: {e}"));
        let packed = pack(&app);
        packed.app.check().unwrap_or_else(|e| panic!("case {case}: {e}"));
        // No consts remain.
        assert!(
            packed.app.iter().all(|(_, n)| !matches!(n.op, AppOp::Const(_))),
            "case {case}: const survived"
        );
        // Net count never increases.
        assert!(packed.app.nets().len() <= app.nets().len(), "case {case}");
    }
}

/// Property: random mux configurations encode/decode through the packed
/// bitstream losslessly.
#[test]
fn prop_bitstream_roundtrip_random_configs() {
    let mut rng = Rng::new(0xDECADE);
    for case in 0..40 {
        let cfg = random_config(&mut rng);
        let ic = create_uniform_interconnect(&cfg);
        let cs = allocate(&ic);
        let mut config = Configuration::default();
        for (&bw, g) in &ic.graphs {
            for id in g.mux_nodes() {
                if rng.below(3) == 0 {
                    let fan = g.fan_in(id).len();
                    config.selects.insert((bw, id), rng.below(fan) as u32);
                }
            }
        }
        let back = decode(&encode(&config, &cs), &cs);
        for (k, v) in &config.selects {
            assert_eq!(back.selects.get(k), Some(v), "case {case}: select lost at {k:?}");
        }
    }
}

/// Property: SA always returns a legal placement, regardless of γ/α.
#[test]
fn prop_sa_preserves_legality() {
    let mut rng = Rng::new(0xFADE);
    for case in 0..25 {
        let cfg = InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3,
            mem_column_period: 3,
            reg_density: 0,
            ..Default::default()
        };
        let ic = create_uniform_interconnect(&cfg);
        let max_nodes = 6 + rng.below(12);
        let app = random_app(&mut rng, max_nodes);
        let packed = pack(&app).app;
        let n = packed.len();
        // Random (legal) initial placement via legalize on random coords.
        let xs: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 5.0).collect();
        let ys: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 5.0).collect();
        let Ok(initial) = legalize(&packed, &ic, &xs, &ys) else {
            continue; // app too MEM-heavy for this array: skip
        };
        let params = SaParams {
            gamma: rng.f64(),
            alpha: 1.0 + rng.f64() * 19.0,
            moves_per_node: 5,
            seed: case,
            ..Default::default()
        };
        let nets = packed.nets();
        let (placed, _) = detailed_place(&packed, &ic, &nets, initial, &params);
        placed.check(&packed, &ic).unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

/// Property: successful routings pass the full shared legality suite —
/// node-disjoint, edge-respecting, connected Steiner subtrees, and
/// fan-in-ordered mux selects (`common::route_check`).
#[test]
fn prop_routes_disjoint_and_valid() {
    let mut rng = Rng::new(0xAB1E);
    let cfg = InterconnectConfig::paper_baseline(8, 8);
    let ic = create_uniform_interconnect(&cfg);
    for case in 0..20 {
        let max_nodes = 8 + rng.below(16);
        let app = random_app(&mut rng, max_nodes);
        let packed = pack(&app).app;
        let n = packed.len();
        let xs: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 7.0).collect();
        let ys: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 7.0).collect();
        let Ok(placement) = legalize(&packed, &ic, &xs, &ys) else { continue };
        let Ok(result) = route(&ic, &packed, &placement, 16, &RouterParams::default()) else {
            continue;
        };
        assert_routing_legal(&ic, 16, &result, packed.nets().len(), &format!("case {case}"));
    }
}

/// Property: placement legality checker agrees with construction — a
/// shuffled placement that doubles up tiles must be rejected.
#[test]
fn prop_placement_checker_catches_overlap() {
    let mut rng = Rng::new(0x5EED);
    let cfg = InterconnectConfig { width: 6, height: 6, num_tracks: 2, ..Default::default() };
    let ic = create_uniform_interconnect(&cfg);
    for case in 0..30 {
        let app = random_app(&mut rng, 10);
        let packed = pack(&app).app;
        if packed.len() < 3 {
            continue;
        }
        let n = packed.len();
        let xs: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 5.0).collect();
        let ys: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 5.0).collect();
        let Ok(placement) = legalize(&packed, &ic, &xs, &ys) else { continue };
        // Corrupt: copy vertex 0's tile onto vertex 1.
        let mut bad = Placement { pos: placement.pos.clone() };
        bad.pos[1] = bad.pos[0];
        assert!(bad.check(&packed, &ic).is_err(), "case {case}: overlap not caught");
    }
}

/// Property: the dynamic-NoC lowering produces loop-free, complete,
/// minimal routing tables on every random full-mesh interconnect.
#[test]
fn prop_noc_tables_valid_on_random_configs() {
    use canal::hw::{hop_count, lower_dynamic, verify_tables, DynOptions};
    let mut rng = Rng::new(0xD0C5);
    for case in 0..25 {
        let cfg = random_config(&mut rng);
        let ic = create_uniform_interconnect(&cfg);
        let noc = lower_dynamic(&ic, *cfg.track_widths.last().unwrap(), &DynOptions::default());
        verify_tables(&noc).unwrap_or_else(|e| panic!("case {case}: {e}"));
        // Spot-check minimality on random pairs (full mesh => manhattan).
        for _ in 0..10 {
            let a = (rng.below(cfg.width as usize) as u16, rng.below(cfg.height as usize) as u16);
            let b = (rng.below(cfg.width as usize) as u16, rng.below(cfg.height as usize) as u16);
            let hops = hop_count(&noc, a, b).unwrap_or_else(|| panic!("case {case}: no route"));
            let manhattan = (a.0 as i32 - b.0 as i32).unsigned_abs()
                + (a.1 as i32 - b.1 as i32).unsigned_abs();
            assert_eq!(hops, manhattan, "case {case}: {a:?}->{b:?}");
        }
    }
}

/// Property — the paper's §4.2.1 mechanism: in a Disjoint fabric, every
/// SB endpoint reachable from a track-t endpoint is itself on track t
/// (routes are confined to their starting track); Wilton escapes the
/// plane within a couple of turns.
#[test]
fn prop_disjoint_confines_routes_to_their_track() {
    use canal::ir::{NodeKind, SbIo, Side};
    let mk = |topo| {
        create_uniform_interconnect(&InterconnectConfig {
            width: 5,
            height: 5,
            num_tracks: 4,
            reg_density: 0,
            mem_column_period: 0,
            sb_topology: topo,
            ..Default::default()
        })
    };
    let reachable_tracks = |ic: &canal::ir::Interconnect, start_track: u16| {
        let g = ic.graph(16);
        let start = g.find_sb(2, 2, Side::East, SbIo::Out, start_track).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![start];
        let mut tracks = std::collections::HashSet::new();
        while let Some(n) = stack.pop() {
            if !seen.insert(n) {
                continue;
            }
            if let NodeKind::SwitchBox { track, .. } = g.node(n).kind {
                tracks.insert(track);
            }
            for &s in g.fan_out(n) {
                // Stay on the fabric (ports would start a new net).
                if !g.node(s).kind.is_port() {
                    stack.push(s);
                }
            }
        }
        tracks
    };
    let dj = mk(SbTopology::Disjoint);
    let wi = mk(SbTopology::Wilton);
    for t in 0..4u16 {
        let dtracks = reachable_tracks(&dj, t);
        assert_eq!(
            dtracks,
            std::collections::HashSet::from([t]),
            "disjoint track {t} escaped its plane: {dtracks:?}"
        );
        let wtracks = reachable_tracks(&wi, t);
        assert!(wtracks.len() >= 3, "wilton track {t} reaches only {wtracks:?}");
    }
}

/// Property: the pinned-output fabric is structurally valid and its SB
/// muxes are strictly smaller than the all-tracks fabric's, while a
/// simple app still routes on Wilton.
#[test]
fn prop_pinned_output_fabric_routes_on_wilton() {
    use canal::dsl::OutputTrackMode;
    use canal::pnr::{run_flow, FlowParams};
    let mut rng = Rng::new(0x71E5);
    for case in 0..10 {
        let mut cfg = random_config(&mut rng);
        cfg.sb_topology = SbTopology::Wilton;
        cfg.num_tracks = 3 + rng.below(3) as u16;
        cfg.width = 6;
        cfg.height = 6;
        cfg.mem_column_period = 3;
        cfg.output_tracks = OutputTrackMode::Pinned;
        let ic = create_uniform_interconnect(&cfg);
        assert!(validate(&ic).is_empty(), "case {case}");
        let mut all = cfg.clone();
        all.output_tracks = OutputTrackMode::AllTracks;
        let ic_all = create_uniform_interconnect(&all);
        assert!(
            ic.edge_count() < ic_all.edge_count(),
            "case {case}: pinning must remove edges"
        );
        let app = random_app(&mut rng, 8);
        let params = FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        };
        run_flow(&ic, &app, &params).unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

/// Property: bitstream disassembly lists exactly one line per configured
/// field and never reports an invalid select, across random apps.
#[test]
fn prop_disassembly_complete_and_valid() {
    use canal::bitstream::disassemble;
    use canal::pnr::{run_flow, FlowParams};
    let mut rng = Rng::new(0xD15A);
    let cfg = InterconnectConfig { width: 6, height: 6, mem_column_period: 3, ..Default::default() };
    let ic = create_uniform_interconnect(&cfg);
    let cs = allocate(&ic);
    for case in 0..10 {
        let app = random_app(&mut rng, 12);
        let params = FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        };
        let Ok(r) = run_flow(&ic, &app, &params) else { continue };
        let cfg16 = Configuration::from_routing(&ic, 16, &r.routing).unwrap();
        let bits = encode(&cfg16, &cs);
        let dis = disassemble(&bits, &cs, &ic);
        // Bitstream writes are word-granular, so disassembly covers every
        // field of each written word — a superset of the explicit config.
        assert!(
            dis.lines().count() >= cfg16.selects.len() + cfg16.reg_modes.len(),
            "case {case}"
        );
        assert!(!dis.contains("<invalid"), "case {case}: {dis}");
        // Every configured mux appears with its actual selected driver.
        let g = ic.graph(16);
        for (&(_, node), &sel) in &cfg16.selects {
            let n = g.node(node);
            let driver = g.node(g.fan_in(node)[sel as usize]).qualified_name();
            let line = format!(
                "({:>2},{:>2}) w16 {} <= {}",
                n.x, n.y, n.kind.label(), driver
            );
            assert!(dis.contains(&line), "case {case}: missing `{line}`");
        }
    }
}

/// Property: the frozen CSR `CompiledGraph` is observationally equivalent
/// to the builder `RoutingGraph` it was compiled from, on random
/// DSL-built interconnects — same fan-in order (mux-select encodings),
/// same fan-out sets, same wire delays, same node attributes.
#[test]
fn prop_compiled_graph_matches_routing_graph() {
    let mut rng = Rng::new(0xC5A11);
    for case in 0..40 {
        let cfg = random_config(&mut rng);
        let ic = create_uniform_interconnect(&cfg);
        for bw in ic.bit_widths() {
            let g = ic.graph(bw);
            let c = ic.compiled(bw);
            assert_eq!(g.width, c.width, "case {case}");
            assert_eq!(g.len(), c.len(), "case {case}");
            assert_eq!(g.edge_count(), c.edge_count(), "case {case}");
            for (id, n) in g.iter() {
                // Fan-in order IS the mux-select encoding; it must
                // survive the freeze exactly.
                assert_eq!(g.fan_in(id), c.fan_in(id), "case {case}: fan-in of {id}");
                assert_eq!(g.fan_out(id), c.fan_out(id), "case {case}: fan-out of {id}");
                assert_eq!(
                    (n.x, n.y, n.delay_ps),
                    (c.x(id), c.y(id), c.node_delay_ps(id)),
                    "case {case}: attrs of {id}"
                );
                assert_eq!(n.kind.is_port(), c.is_port(id), "case {case}");
                assert_eq!(n.kind.is_register(), c.is_register(id), "case {case}");
                for &src in g.fan_in(id) {
                    assert_eq!(
                        g.wire_delay(src, id),
                        c.wire_delay(src, id),
                        "case {case}: delay {src} -> {id}"
                    );
                    assert_eq!(
                        g.select_of(id, src),
                        c.select_of(id, src),
                        "case {case}: select {src} -> {id}"
                    );
                }
                let max_wire =
                    g.fan_out(id).iter().map(|&s| g.wire_delay(id, s)).max().unwrap_or(0);
                assert_eq!(max_wire, c.max_out_wire_delay(id), "case {case}");
            }
        }
    }
}

/// End to end: routing Harris through the compiled hot path yields a
/// bitstream bit-identical to one whose selects are re-derived from the
/// builder graph's insertion-order adjacency (the seed path's semantics).
#[test]
fn e2e_compiled_harris_bitstream_matches_builder_graph_path() {
    use canal::pnr::{run_flow, FlowParams};
    let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
    let params = FlowParams {
        sa: SaParams { moves_per_node: 8, ..Default::default() },
        ..Default::default()
    };
    let r = run_flow(&ic, &canal::apps::harris(), &params).unwrap();

    // Hot path: selects derived via the CompiledGraph (the normal API).
    let via_compiled = Configuration::from_routing(&ic, 16, &r.routing).unwrap();

    // Reference path: every select recomputed from the builder graph.
    let g = ic.graph(16);
    let mut reference = Configuration::default();
    for tree in &r.routing.trees {
        for path in &tree.sink_paths {
            for w in path.windows(2) {
                let (a, b) = (w[0], w[1]);
                if g.fan_in(b).len() > 1 {
                    let sel = g.select_of(b, a).expect("route uses a real edge") as u32;
                    reference.selects.insert((16, b), sel);
                }
                if g.node(b).kind.is_register() {
                    reference.reg_modes.insert((16, b), 0);
                }
            }
        }
    }
    assert_eq!(via_compiled, reference);

    let cs = allocate(&ic);
    let hot = encode(&via_compiled, &cs).to_text();
    let seed = encode(&reference, &cs).to_text();
    assert_eq!(hot, seed, "compiled-path bitstream must be bit-identical");
    assert!(!hot.is_empty());
}

/// Property: the NoC simulator delivers exactly tokens x sink-edges
/// packets for every random placed app, with latency at least the hop
/// count of the farthest flow.
#[test]
fn prop_noc_sim_conserves_packets() {
    use canal::hw::{lower_dynamic, DynOptions};
    use canal::pnr::{run_flow, FlowParams};
    use canal::sim::NocSim;
    let mut rng = Rng::new(0x10C5);
    let cfg = InterconnectConfig { width: 6, height: 6, mem_column_period: 3, ..Default::default() };
    let ic = create_uniform_interconnect(&cfg);
    let noc = lower_dynamic(&ic, 16, &DynOptions::default());
    for case in 0..10 {
        let app = random_app(&mut rng, 12);
        let params = FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        };
        let Ok(r) = run_flow(&ic, &app, &params) else { continue };
        let packed = pack(&app).app;
        let tokens = 16;
        let run = NocSim::new(&noc, &packed, &r.placement).run(tokens, 1, 1_000_000);
        let sink_edges: usize = packed.nets().iter().map(|n| n.sinks.len()).sum();
        assert_eq!(run.delivered, tokens * sink_edges, "case {case}");
        assert!(run.cycles >= tokens as u64, "case {case}");
    }
}
