//! Loopback end-to-end tests of the Canal daemon: a real TCP server on
//! an ephemeral port, real clients, real frames.
//!
//! The acceptance contract asserted here:
//! - K concurrent clients issuing overlapping `dse` sweeps receive
//!   results **bit-identical** to the sequential in-process engine;
//! - however the requests interleave, each unique `(config, app, seed)`
//!   job is placed-and-routed at most once per daemon lifetime;
//! - a repeated identical request performs **zero PnR calls and zero
//!   simulations**, observable through the per-request stats embedded
//!   in the result frame AND the cumulative `stats` frame;
//! - malformed frames and mid-request disconnects are contained to
//!   their connection — the daemon keeps serving;
//! - `shutdown` drains gracefully and flushes the shared cache file.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use canal::dse::{DseEngine, ResultCache, SweepOutcome};
use canal::pnr::BatchedNativePlacer;
use canal::service::proto::{point_result_from_json, request_line};
use canal::service::{
    Client, DseParams, Frame, GenParams, Request, ServeOptions, Server, SessionState,
    SimParams, StateOptions, PROTO_VERSION,
};
use canal::util::json::Json;

/// Bind a daemon on an ephemeral loopback port with a pinned native
/// placer (so references computed in-process share the cache identity).
fn spawn_server(
    cache_path: Option<std::path::PathBuf>,
) -> (std::net::SocketAddr, Arc<SessionState>, std::thread::JoinHandle<Result<(), String>>) {
    let state = Arc::new(
        SessionState::with_placer(
            StateOptions { workers: 2, cache_path, ic_capacity: 8 },
            Box::new(BatchedNativePlacer::default()),
        )
        .unwrap(),
    );
    let server = Server::bind_with_state(
        ServeOptions {
            addr: "127.0.0.1:0".into(),
            conn_threads: 6,
            ..Default::default()
        },
        Arc::clone(&state),
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());
    (addr, state, handle)
}

/// The standard tiny sweep: 2 configs × 1 app × 1 seed on a 4x4 array.
fn tiny_params() -> DseParams {
    DseParams {
        width: 4,
        height: 4,
        tracks: vec![2, 3],
        apps: vec!["pointwise4".into()],
        sa_moves: 4,
        ..Default::default()
    }
}

/// In-process reference for a parameter set — the sequential CLI path.
fn reference_for(params: &DseParams) -> SweepOutcome {
    let mut engine = DseEngine::in_memory();
    engine.run(&params.to_spec(), &BatchedNativePlacer::default()).unwrap()
}

/// Every wire point must match the reference bit-for-bit.
fn assert_points_match(data: &Json, reference: &SweepOutcome) {
    let points = data.get("points").and_then(Json::as_arr).expect("points array");
    assert_eq!(points.len(), reference.points.len());
    for (wire, (job, direct)) in points.iter().zip(&reference.points) {
        assert_eq!(
            wire.get("config").and_then(Json::as_str),
            Some(job.key.config.0.as_str())
        );
        assert_eq!(wire.get("app").and_then(Json::as_str), Some(job.key.app.as_str()));
        assert_eq!(wire.get("seed").and_then(Json::as_u64), Some(job.key.seed));
        let r = point_result_from_json(wire).unwrap();
        assert_eq!(&r, direct, "daemon point must be bit-identical to the engine");
        assert_eq!(r.runtime_ns.to_bits(), direct.runtime_ns.to_bits());
        assert_eq!(r.critical_path_ps.to_bits(), direct.critical_path_ps.to_bits());
    }
}

#[test]
fn concurrent_clients_bit_identical_then_warm_rerun_zero_pnr_zero_sims() {
    let (addr, state, handle) = spawn_server(None);
    let params = tiny_params();
    let reference = reference_for(&params);

    // Phase 1: 4 concurrent clients fire the same sweep at once.
    let results: Vec<Json> = std::thread::scope(|scope| {
        let barrier = std::sync::Barrier::new(4);
        let mut joins = Vec::new();
        for _ in 0..4 {
            let (barrier, params) = (&barrier, &params);
            joins.push(scope.spawn(move || {
                let mut c = Client::connect(&addr.to_string()).unwrap();
                barrier.wait();
                c.call(&Request::Dse(params.clone())).unwrap()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    for data in &results {
        assert_points_match(data, &reference);
    }
    // All sessions together computed each unique job exactly once.
    assert_eq!(state.stats().pnr_runs.load(Ordering::Relaxed), 2);
    assert_eq!(state.stats().sims.load(Ordering::Relaxed), 2);

    // Phase 2: a repeated identical request is served entirely from the
    // warm SessionState — the result frame's own stats prove it.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let warm = c.call(&Request::Dse(params.clone())).unwrap();
    let stats = warm.get("stats").expect("per-request stats");
    assert_eq!(stats.get("pnr_runs").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("sims").and_then(Json::as_u64), Some(0));
    assert_eq!(stats.get("cache_hits").and_then(Json::as_u64), Some(2));
    assert_points_match(&warm, &reference);

    // ...and so does the cumulative stats frame.
    let global = c.call(&Request::Stats).unwrap();
    assert_eq!(global.get("pnr_runs").and_then(Json::as_u64), Some(2));
    assert_eq!(global.get("sims").and_then(Json::as_u64), Some(2));
    assert!(global.get("cache_entries").and_then(Json::as_u64) >= Some(2));

    let bye = c.call(&Request::Shutdown).unwrap();
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();
}

#[test]
fn malformed_frames_and_mid_request_disconnects_are_contained() {
    use std::io::{BufRead, BufReader, Write};
    let (addr, state, handle) = spawn_server(None);

    // A malformed line gets an id-0 error frame and closes THAT
    // connection only.
    {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(b"this is not a frame\n").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        match Frame::parse(line.trim_end()).unwrap() {
            Frame::Error { id, error } => {
                assert_eq!(id, 0);
                assert!(error.contains("malformed"), "{error}");
            }
            other => panic!("expected an error frame, got {other:?}"),
        }
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must be closed");
    }

    // Mid-request disconnect: fire a cold sweep and hang up before any
    // frame comes back. The daemon finishes the work and caches it.
    {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let line = format!("{}\n", request_line(1, &Request::Dse(tiny_params())));
        s.write_all(line.as_bytes()).unwrap();
        drop(s);
    }

    // A fresh session asking for the same sweep gets correct, complete
    // results — by joining the abandoned computation or hitting its
    // cached output, never by recomputing.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let data = c.call(&Request::Dse(tiny_params())).unwrap();
    assert_points_match(&data, &reference_for(&tiny_params()));
    // The abandoned request absorbs its counters asynchronously; poll
    // briefly, then assert nothing was computed twice.
    for _ in 0..200 {
        if state.stats().pnr_runs.load(Ordering::Relaxed) >= 2 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(state.stats().pnr_runs.load(Ordering::Relaxed), 2);

    // The daemon is still healthy.
    let pong = c.call(&Request::Ping).unwrap();
    assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn full_request_surface_roundtrips_on_one_connection() {
    let (addr, _state, handle) = spawn_server(None);
    let mut c = Client::connect(&addr.to_string()).unwrap();

    let pong = c.call(&Request::Ping).unwrap();
    assert_eq!(pong.get("proto").and_then(Json::as_u64), Some(PROTO_VERSION));

    let info = c.call(&Request::Info).unwrap();
    assert_eq!(info.get("placer").and_then(Json::as_str), Some("native-gd"));
    assert!(info.get("apps").and_then(Json::as_arr).unwrap().len() >= 6);

    let gen = c
        .call(&Request::Generate(GenParams { width: 4, height: 4, ..Default::default() }))
        .unwrap();
    assert!(gen.get("nodes").and_then(Json::as_u64).unwrap() > 0);
    assert!(gen.get("config_bits").and_then(Json::as_u64).unwrap() > 0);
    assert!(gen.get("modules").and_then(|m| m.get("mux")).is_some());

    let sim = c
        .call(&Request::Simulate(SimParams {
            app: "gaussian".into(),
            tokens: 32,
            ..Default::default()
        }))
        .unwrap();
    assert_eq!(sim.get("tokens").and_then(Json::as_u64), Some(32));
    assert!(sim.get("cycles").and_then(Json::as_u64).unwrap() >= 32);

    // `pnr` is a one-job sweep through the shared cache.
    let pnr = c
        .call(&Request::Pnr(DseParams { apps: vec!["pointwise4".into()], ..tiny_params() }))
        .unwrap();
    let points = pnr.get("points").and_then(Json::as_arr).unwrap();
    assert_eq!(points.len(), 2, "tiny_params sweeps two track counts");
    assert_eq!(points[0].get("routed").and_then(Json::as_bool), Some(true));

    // Request-level errors keep the connection serving.
    assert!(c.call(&Request::Pnr(DseParams::default())).is_err());
    assert!(c.call(&Request::Dse(DseParams::default())).is_err(), "nothing to do");
    assert!(c
        .call(&Request::Simulate(SimParams { app: "nope".into(), ..Default::default() }))
        .is_err());

    let area = c
        .call(&Request::Area(DseParams {
            width: 4,
            height: 4,
            tracks: vec![2, 3],
            ..Default::default()
        }))
        .unwrap();
    assert_eq!(area.get("areas").and_then(Json::as_arr).unwrap().len(), 2);
    assert!(area
        .get("areas_table")
        .and_then(Json::as_str)
        .unwrap()
        .contains("sb_area_um2"));

    // fig10 is area-only: a cheap end-to-end figure regeneration.
    let fig = c.call(&Request::Figure { which: "fig10".into(), sa_moves: 4 }).unwrap();
    assert!(fig.get("table").and_then(Json::as_str).unwrap().contains("Fig. 10"));
    assert!(c.call(&Request::Figure { which: "fig99".into(), sa_moves: 4 }).is_err());

    // Progress frames stream ahead of the terminal result.
    let mut progress = Vec::new();
    let _ = c
        .call_with(&Request::Dse(tiny_params()), |m| progress.push(m.to_string()))
        .unwrap();
    assert!(!progress.is_empty(), "dse requests must stream progress");
    assert!(progress.iter().any(|m| m.contains("jobs")), "{progress:?}");

    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn metrics_request_reflects_engine_and_service_activity() {
    // The daemon enables the metrics gate at bind, so a `metrics`
    // request after a sweep must show both layers: `engine.*` counters
    // mirrored from the sweep's stats and `service.request.*` counters
    // from the request accounting. The registry is process-global and
    // other tests in this binary run concurrently — every assertion is
    // a lower bound, never an exact count.
    let (addr, _state, handle) = spawn_server(None);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.call(&Request::Dse(tiny_params())).unwrap();

    // Same connection ⇒ the dse request's counters land before the
    // metrics request is read.
    let data = c.call(&Request::Metrics).unwrap();
    let metrics = data.get("metrics").and_then(Json::as_arr).expect("metrics array");
    let counter = |name: &str| -> Option<u64> {
        metrics
            .iter()
            .find(|m| m.get("metric").and_then(Json::as_str) == Some(name))
            .and_then(|m| m.get("value").and_then(Json::as_u64))
    };
    assert!(counter("engine.jobs") >= Some(2), "sweep stats mirrored: {:?}", counter("engine.jobs"));
    assert!(counter("engine.sweeps") >= Some(1));
    assert!(counter("service.request.dse") >= Some(1), "per-command request counter");
    assert!(
        metrics
            .iter()
            .any(|m| m.get("metric").and_then(Json::as_str) == Some("service.request.latency_us")
                && m.get("count").and_then(Json::as_u64) >= Some(1)),
        "request latency histogram populated"
    );
    assert!(counter("service.conn.bytes_read") >= Some(1), "connection read accounting");

    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn heartbeats_carry_live_sweep_progress_mid_sweep() {
    // Shrink the heartbeat far below the sweep duration: the progress
    // frames streamed during the cold sweep must include live
    // heartbeats in the `progress: done/total jobs (...)` format fed by
    // the executor's SweepProgress — not just the bare begin/end frames.
    let state = Arc::new(
        SessionState::with_placer(
            StateOptions { workers: 2, cache_path: None, ic_capacity: 8 },
            Box::new(BatchedNativePlacer::default()),
        )
        .unwrap(),
    );
    let server = Server::bind_with_state(
        ServeOptions {
            addr: "127.0.0.1:0".into(),
            conn_threads: 2,
            heartbeat: std::time::Duration::from_millis(1),
            ..Default::default()
        },
        Arc::clone(&state),
    )
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run());

    // Enough work to straddle many 1ms heartbeats.
    let params = DseParams { seeds: 2, sa_moves: 200, ..tiny_params() };
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let mut frames = Vec::new();
    let data = c
        .call_with(&Request::Dse(params.clone()), |m| frames.push(m.to_string()))
        .unwrap();
    assert_points_match(&data, &reference_for(&params));

    let live: Vec<&String> =
        frames.iter().filter(|m| m.starts_with("progress: ")).collect();
    assert!(!live.is_empty(), "no live heartbeat among {frames:?}");
    for m in &live {
        // "progress: D/T jobs (H cached, C coalesced, d/t cold...)[, util ...]"
        assert!(m.contains(" jobs ("), "malformed heartbeat: {m}");
        assert!(m.contains(" cold"), "cold split missing: {m}");
    }
    // Utilization appears once workers have registered — a heartbeat
    // can legitimately fire earlier, but not ALL of them may.
    assert!(
        live.iter().any(|m| m.contains("util w")),
        "no heartbeat carried per-worker utilization: {live:?}"
    );
    // The final heartbeat seen can never overshoot the job total.
    let total = 4; // 2 tracks × 1 app × 2 seeds
    for m in &live {
        let done: u64 = m["progress: ".len()..]
            .split('/')
            .next()
            .and_then(|s| s.parse().ok())
            .expect("parsable done count");
        assert!(done <= total, "{m}");
    }

    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn http_dash_on_the_ndjson_port_reflects_a_just_run_sweep() {
    use std::io::{Read, Write};
    let (addr, _state, handle) = spawn_server(None);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.call(&Request::Dse(tiny_params())).unwrap();

    // Plain HTTP/1.1 on the NDJSON port: the server sniffs the `GET `
    // prefix and answers one response, then closes.
    let http_get = |path: &str| -> String {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(
            format!("GET {path} HTTP/1.1\r\nHost: canal\r\nConnection: close\r\n\r\n")
                .as_bytes(),
        )
        .unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        buf
    };

    let dash = http_get("/dash");
    assert!(dash.starts_with("HTTP/1.1 200 OK\r\n"), "{}", &dash[..dash.len().min(80)]);
    assert!(dash.contains("Content-Type: text/html"));
    assert!(dash.contains("<!DOCTYPE html>"));
    assert!(dash.contains("<svg"), "charts are inline SVG");
    assert!(
        dash.contains("service.request.dse"),
        "the metrics table reflects the sweep this test just ran"
    );
    assert!(!dash.contains("<script"), "self-contained page: no JS");
    assert!(!dash.contains("<link"), "self-contained page: no external CSS");

    let metrics = http_get("/metrics.json");
    assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"));
    assert!(metrics.contains("Content-Type: application/json"));
    let body = metrics.split("\r\n\r\n").nth(1).expect("body after headers");
    let doc = Json::parse(body).expect("metrics body is valid JSON");
    assert!(doc.get("ts_ms").and_then(Json::as_u64).unwrap_or(0) > 0);
    assert!(doc.get("metrics").and_then(Json::as_arr).is_some());

    let archive = http_get("/archive.json");
    let body = archive.split("\r\n\r\n").nth(1).unwrap();
    let doc = Json::parse(body).expect("archive body is valid JSON");
    assert!(doc.get("entries").and_then(Json::as_arr).is_some());

    assert!(http_get("/nope").starts_with("HTTP/1.1 404"));

    // NDJSON clients on the same port are unaffected by HTTP traffic.
    let pong = c.call(&Request::Ping).unwrap();
    assert_eq!(pong.get("pong").and_then(Json::as_bool), Some(true));
    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn watch_streams_monotone_timestamped_history_frames() {
    let (addr, _state, handle) = spawn_server(None);

    // One-shot `history` first: the full ring document with its cursor.
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let hist = c.call(&Request::History).unwrap();
    assert!(hist.get("period_ms").and_then(Json::as_u64).unwrap_or(0) > 0);
    assert!(hist.get("capacity").and_then(Json::as_u64).unwrap_or(0) > 0);
    assert!(hist.get("samples").and_then(Json::as_arr).is_some());

    // `watch` never terminates on its own: collect a few delta frames
    // on a dedicated connection, then stop via the callback.
    let mut w = Client::connect(&addr.to_string()).unwrap();
    let mut stamps = Vec::new();
    let out = w
        .call_frames(&Request::Watch, |frame| {
            if let Frame::History { ts_ms, mono_ns, .. } = frame {
                assert!(*ts_ms > 0, "every history frame carries a wall stamp");
                stamps.push(*mono_ns);
            }
            stamps.len() < 3
        })
        .unwrap();
    assert!(out.is_none(), "watch must never send a terminal frame");
    assert_eq!(stamps.len(), 3);
    assert!(
        stamps.windows(2).all(|p| p[0] < p[1]),
        "frames strictly monotone in mono_ns: {stamps:?}"
    );

    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn shutdown_drains_and_flushes_the_shared_cache_file() {
    let path = std::env::temp_dir()
        .join(format!("canal_service_e2e_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let (addr, _state, handle) = spawn_server(Some(path.clone()));
    let mut c = Client::connect(&addr.to_string()).unwrap();
    c.call(&Request::Dse(tiny_params())).unwrap();
    let bye = c.call(&Request::Shutdown).unwrap();
    assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(bye.get("flushed").and_then(Json::as_bool), Some(true));
    handle.join().unwrap().unwrap();

    // The flushed file holds every computed point and a fresh daemon
    // would come up warm from it.
    let cache = ResultCache::at(&path).unwrap();
    assert_eq!(cache.len(), 2);
    std::fs::remove_file(&path).unwrap();
}
