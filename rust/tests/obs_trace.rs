//! End-to-end validity of the observability layer over a real sweep:
//! a fully-gated DSE run must leave behind (a) a Chrome trace file that
//! parses back and covers every flow stage, and (b) a metrics snapshot
//! whose NDJSON lines parse and whose stage counters are consistent
//! with the engine's own stats.
//!
//! All assertions are lower-bound / filter style — the span rings and
//! the metrics registry are process-global, so a concurrent test (or a
//! second sweep in this file) may add events; nothing here assumes it
//! was the only writer.

use std::sync::{Mutex, MutexGuard};

use canal::dse::{DseEngine, EngineOptions, SweepSpec};
use canal::dsl::InterconnectConfig;
use canal::obs::span::names;
use canal::obs::{self, ObsOptions};
use canal::pnr::{FlowParams, NativePlacer, SaParams};
use canal::util::json::Json;

/// The gate byte and the span rings are process-global, and the tests
/// in this binary run on separate threads: every test that flips the
/// gate or reads ring totals serializes here, so one test's `disabled`
/// window can't swallow another's events.
static GATE_LOCK: Mutex<()> = Mutex::new(());

fn gate_lock() -> MutexGuard<'static, ()> {
    GATE_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        name: "obs-trace".into(),
        base: InterconnectConfig { width: 4, height: 4, mem_column_period: 3, ..Default::default() },
        tracks: vec![2, 3],
        apps: vec!["pointwise4".into()],
        seeds: vec![1],
        flow: FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn traced_sweep_exports_a_valid_chrome_trace_and_metrics_snapshot() {
    let _gate = gate_lock();
    ObsOptions::full().apply();
    let spec = tiny_spec();
    let mut engine =
        DseEngine::new(EngineOptions { workers: 2, cache_path: None, warm_start: false })
            .expect("engine");
    let cold = engine.run(&spec, &NativePlacer::default()).expect("cold sweep");
    // Same engine, same spec: the re-run is all cache hits, so the trace
    // additionally covers the hit path.
    let warm = engine.run(&spec, &NativePlacer::default()).expect("warm sweep");
    ObsOptions::disabled().apply();
    assert_eq!(cold.stats.pnr_runs, cold.points.len() as u64);
    assert_eq!(warm.stats.cache_hits, warm.points.len() as u64);

    // --- span coverage -----------------------------------------------
    let events = obs::span::collect();
    for name in [
        names::PACK,
        names::GLOBAL_PLACE,
        names::LEGALIZE,
        names::SA,
        names::ROUTE,
        names::STA,
        names::SIM,
        names::JOB,
        names::PLACE_BATCH,
        names::CACHE_MISS,
        names::CACHE_HIT,
    ] {
        assert!(
            events.iter().any(|e| e.name == name),
            "traced sweep recorded no `{name}` span/event"
        );
    }
    let routes = events.iter().filter(|e| e.name == names::ROUTE).count() as u64;
    assert!(routes >= cold.stats.pnr_runs, "one route span per cold PnR, minimum");
    // Worker threads label their tracks; the merged stream is
    // (start_ns, worker)-ordered by construction.
    assert!(obs::span::track_labels()
        .iter()
        .any(|(_, label)| label.starts_with("dse-worker-")));
    assert!(events.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));

    // --- the trace file ----------------------------------------------
    let path = std::env::temp_dir()
        .join(format!("canal_obs_trace_{}.json", std::process::id()));
    obs::export::write_chrome_trace(&path).expect("trace written");
    let text = std::fs::read_to_string(&path).expect("trace readable");
    std::fs::remove_file(&path).expect("trace removed");
    let doc = Json::parse(&text).expect("trace file is valid JSON");
    let evs = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("Chrome object format: top-level traceEvents array");
    assert!(evs.len() >= events.len(), "file covers every collected event");
    let mut last_ts = f64::NEG_INFINITY;
    for ev in evs {
        let ph = ev.get("ph").and_then(|v| v.as_str()).expect("every record has ph");
        if ph == "M" {
            continue; // thread_name metadata
        }
        assert!(matches!(ph, "X" | "i"), "only complete spans and instants: {ph}");
        assert!(ev.get("name").and_then(|v| v.as_str()).is_some());
        assert!(ev.get("tid").and_then(|v| v.as_u64()).is_some());
        let ts = ev.get("ts").and_then(|v| v.as_f64()).expect("ts in microseconds");
        assert!(ts >= last_ts, "events stream in timestamp order");
        last_ts = ts;
        if ph == "X" {
            assert!(ev.get("dur").and_then(|v| v.as_f64()).is_some());
        }
    }

    // --- the metrics snapshot ----------------------------------------
    let nd = obs::export::metrics_ndjson();
    let mut route_count = 0;
    for line in nd.lines() {
        let j = Json::parse(line).expect("every NDJSON line parses");
        assert!(j.get("metric").is_some() && j.get("type").is_some());
        if j.get("metric").and_then(Json::as_str) == Some("pnr.route.count") {
            route_count = j.get("value").and_then(|v| v.as_u64()).unwrap_or(0);
        }
    }
    assert!(
        route_count >= cold.stats.pnr_runs,
        "pnr.route.count ({route_count}) must cover the sweep's {} PnR runs",
        cold.stats.pnr_runs
    );
    assert!(nd.contains("\"pnr.route.ns\""), "stage duration histogram registered");
    assert!(nd.contains("\"engine.jobs\""), "engine stats mirrored into the registry");
    assert!(nd.contains("\"obs.span.recorded\""), "ring accounting present");
}

#[test]
fn empty_span_buffer_exports_a_valid_trace() {
    let _gate = gate_lock();
    // No events, no labels — the degenerate document must still be
    // loadable Chrome trace JSON (Perfetto accepts an empty array).
    let doc = obs::export::chrome_trace(&[], &[]);
    let parsed = Json::parse(&doc.render()).expect("empty trace renders valid JSON");
    let evs = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array present");
    assert!(evs.is_empty(), "no events and no metadata records");
    assert_eq!(parsed.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
}

#[test]
fn ring_overflow_is_accounted_and_the_trace_stays_valid() {
    let _gate = gate_lock();
    ObsOptions::full().apply();
    let (_, dropped_before) = obs::span::totals();
    // One dedicated thread gets one fresh ring; pushing past its
    // capacity forces drop-oldest mid-run.
    const EXCESS: u64 = 512;
    let burst = obs::span::DEFAULT_RING_CAPACITY as u64 + EXCESS;
    std::thread::spawn(move || {
        for i in 0..burst {
            obs::event(names::CACHE_HIT, i, 0);
        }
    })
    .join()
    .expect("burst thread");
    let events = obs::span::collect();
    let labels = obs::span::track_labels();
    ObsOptions::disabled().apply();
    let (_, dropped_after) = obs::span::totals();
    assert!(
        dropped_after.saturating_sub(dropped_before) >= EXCESS,
        "overflow must be accounted in obs.span.dropped_events \
         ({dropped_before} -> {dropped_after})"
    );
    // The survivors still export: valid JSON, every event a complete
    // record, count bounded by the ring capacity for that track.
    let doc = obs::export::chrome_trace(&events, &labels);
    let parsed = Json::parse(&doc.render()).expect("overflowed trace renders valid JSON");
    let evs = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!evs.is_empty(), "capacity-many events survive the overflow");
}

#[test]
fn metrics_json_covers_all_three_metric_kinds_with_timestamps() {
    let _gate = gate_lock();
    ObsOptions::metrics_only().apply();
    obs::metrics::counter("test.obs_trace.counter").add(7);
    obs::metrics::gauge("test.obs_trace.gauge").set(-4);
    obs::metrics::histogram("test.obs_trace.hist").record(250);
    let doc = obs::export::metrics_json();
    ObsOptions::disabled().apply();
    assert!(doc.get("ts_ms").and_then(Json::as_u64).unwrap_or(0) > 0, "wall stamp");
    assert!(doc.get("mono_ns").and_then(Json::as_u64).is_some(), "monotonic stamp");
    let metrics = doc.get("metrics").and_then(Json::as_arr).expect("metrics array");
    let find = |name: &str| {
        metrics
            .iter()
            .find(|m| m.get("metric").and_then(Json::as_str) == Some(name))
            .unwrap_or_else(|| panic!("metric `{name}` missing from snapshot"))
    };
    let c = find("test.obs_trace.counter");
    assert_eq!(c.get("type").and_then(Json::as_str), Some("counter"));
    assert!(c.get("value").and_then(Json::as_u64).unwrap_or(0) >= 7);
    let g = find("test.obs_trace.gauge");
    assert_eq!(g.get("type").and_then(Json::as_str), Some("gauge"));
    assert_eq!(g.get("value").and_then(Json::as_f64), Some(-4.0));
    let h = find("test.obs_trace.hist");
    assert_eq!(h.get("type").and_then(Json::as_str), Some("histogram"));
    assert!(h.get("count").and_then(Json::as_u64).unwrap_or(0) >= 1);
    assert!(h.get("p50").and_then(Json::as_f64).is_some());
    assert!(h.get("p99").and_then(Json::as_f64).is_some());
}
