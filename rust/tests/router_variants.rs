//! Router-variant equivalence suite — the lockdown for the pluggable
//! search cores and Steiner-tree routing (PR 8).
//!
//! The contract under test, from strongest to weakest:
//!
//! * **Pure cores are bit-identical.** `bucket` and `radix` are
//!   execution strategies for the same wavefront: on every random
//!   fabric × app × flag combination they must reproduce the
//!   binary-heap router's trees, iteration count, and expansion count
//!   exactly — and therefore its bitstream, its engine `PointResult`s,
//!   and its cache keys.
//! * **Every variant is legal.** `astar`, `bidir`, `slack_order`, and
//!   independent-sink mode may pick different routes, but whatever they
//!   produce must pass the full shared legality suite
//!   (`common::route_check`): every sink reached, connected Steiner
//!   subtrees, node-disjoint nets, fan-in-ordered mux selects.
//! * **Flags off means exactly the old router.** The default
//!   `RouterParams` carries no descriptor tokens, so pre-variant cache
//!   entries keep answering, and the default engine run is the
//!   PathFinder baseline bit-for-bit.
//! * **Slack ordering never loses.** Re-sorting nets by STA slack
//!   between iterations must not slow convergence in aggregate and must
//!   keep every fixture's critical path within the warm-start bar.
//!
//! Random structure comes from the crate's deterministic RNG (the
//! layered-DAG generator mirrors `rv_elasticity.rs`), so failures
//! reproduce from the printed case index.

mod common;

use canal::bitstream::{encode, Configuration};
use canal::dse::{DseEngine, EngineOptions, SweepSpec};
use canal::dsl::{create_uniform_interconnect, ConnectedSides, InterconnectConfig, SbTopology};
use canal::hw::allocate;
use canal::pnr::{
    analyze, legalize, pack, route, run_flow, AppGraph, AppNodeId, AppOp, FlowParams,
    NativePlacer, RouterParams, RoutingResult, SaParams, SearchCore,
};
use canal::util::rng::Rng;

use common::route_check::assert_routing_legal;

/// Random layered feed-forward DAG, same shape discipline as the
/// `rv_elasticity.rs` generator: every vertex feeds forward, compute
/// vertices always have inputs, the survivor drains to a stream sink.
/// Register insertion and constant operands vary the net mix; frontier
/// reuse (the linebuffer branch and pair reduction) produces the
/// multi-fanout nets the Steiner invariants need.
fn random_app(seed: u64) -> AppGraph {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ 0xE1A5_71C0);
    let mut g = AppGraph::new(&format!("rand{seed}"));
    let mut uid = 0usize;
    let fresh = |prefix: &str, uid: &mut usize| {
        *uid += 1;
        format!("{prefix}{uid}")
    };

    let n_inputs = 1 + rng.below(2);
    let mut pool: Vec<AppNodeId> =
        (0..n_inputs).map(|i| g.mem(&format!("in{i}"), "stream_in")).collect();
    // Widen the frontier off the first input so it fans out.
    if rng.below(2) == 0 {
        let lb = g.mem(&fresh("lb", &mut uid), "linebuffer");
        g.wire(pool[0], lb, 0);
        pool.push(lb);
    }

    let binary_ops = ["add", "sub", "mul", "max", "min"];
    let mut layers = 2 + rng.below(3);
    while pool.len() > 1 || layers > 0 {
        layers = layers.saturating_sub(1);
        let mut next = Vec::new();
        let mut i = 0;
        while i < pool.len() {
            let mut a = pool[i];
            if rng.below(4) == 0 {
                let r = g.add(&fresh("r", &mut uid), AppOp::Reg);
                g.wire(a, r, 0);
                a = r;
            }
            if i + 1 < pool.len() {
                let b = pool[i + 1];
                let op = binary_ops[rng.below(binary_ops.len())];
                let v = g.alu(&fresh("v", &mut uid), op);
                g.wire(a, v, 0);
                g.wire(b, v, 1);
                next.push(v);
                i += 2;
            } else {
                let k =
                    g.add(&fresh("k", &mut uid), AppOp::Const(1 + rng.below(7) as i64));
                let op = binary_ops[rng.below(binary_ops.len())];
                let v = g.alu(&fresh("c", &mut uid), op);
                g.wire(a, v, 0);
                g.wire(k, v, 1);
                next.push(v);
                i += 1;
            }
        }
        pool = next;
        if pool.len() == 1 && layers == 0 {
            break;
        }
    }
    let out = g.mem("out", "stream_out");
    g.wire(pool[0], out, 0);
    g.check().unwrap_or_else(|e| panic!("random_app({seed}) malformed: {e}"));
    g
}

/// Random interconnect over the variant envelope the issue names:
/// tracks 2–5, all three switch-box topologies, 2–4 connected sides.
fn random_config(rng: &mut Rng) -> InterconnectConfig {
    InterconnectConfig {
        width: 5 + rng.below(2) as u16,
        height: 5 + rng.below(2) as u16,
        num_tracks: 2 + rng.below(4) as u16,
        sb_topology: [SbTopology::Wilton, SbTopology::Disjoint, SbTopology::Imran]
            [rng.below(3)],
        sb_core_sides: ConnectedSides(2 + rng.below(3) as u8),
        cb_core_sides: ConnectedSides(2 + rng.below(3) as u8),
        mem_column_period: 3,
        ..Default::default()
    }
}

fn trees_identical(a: &RoutingResult, b: &RoutingResult, ctx: &str) {
    assert_eq!(a.trees.len(), b.trees.len(), "{ctx}: tree count");
    for (i, (ta, tb)) in a.trees.iter().zip(&b.trees).enumerate() {
        assert_eq!(ta.sink_paths, tb.sink_paths, "{ctx}: net {i} routed differently");
    }
    assert_eq!(a.iterations, b.iterations, "{ctx}: iteration count");
    assert_eq!(a.nodes_used, b.nodes_used, "{ctx}: nodes used");
    assert_eq!(a.route_expansions, b.route_expansions, "{ctx}: expansion count");
}

/// The core property: random fabric × random layered DAG × every
/// `(search core, steiner, slack_order)` combination. Successful routes
/// pass the full legality suite; `bucket`/`radix` reproduce the
/// binary-heap result exactly under every flag setting (including
/// whether routing succeeds at all).
#[test]
fn every_core_and_flag_combination_is_legal_and_pure_cores_are_bit_identical() {
    let mut rng = Rng::new(0x8_0075);
    for case in 0..8u64 {
        let cfg = random_config(&mut rng);
        let ic = create_uniform_interconnect(&cfg);
        let packed = pack(&random_app(case + 1)).app;
        let n = packed.len();
        let w = cfg.width as f64 - 1.0;
        let h = cfg.height as f64 - 1.0;
        let xs: Vec<f32> = (0..n).map(|_| (rng.f64() * w) as f32).collect();
        let ys: Vec<f32> = (0..n).map(|_| (rng.f64() * h) as f32).collect();
        let Ok(placement) = legalize(&packed, &ic, &xs, &ys) else { continue };

        for steiner in [true, false] {
            for slack_order in [true, false] {
                // Binary-heap first: it is the reference the pure cores
                // must reproduce under these same flags.
                let heap = route(
                    &ic,
                    &packed,
                    &placement,
                    16,
                    &RouterParams {
                        search_core: SearchCore::BinaryHeap,
                        steiner,
                        slack_order,
                        ..Default::default()
                    },
                );
                for core in SearchCore::ALL {
                    let ctx = format!(
                        "case {case} core={} steiner={steiner} slack={slack_order}",
                        core.name()
                    );
                    let params = RouterParams {
                        search_core: core,
                        steiner,
                        slack_order,
                        ..Default::default()
                    };
                    let result = route(&ic, &packed, &placement, 16, &params);
                    if let Ok(r) = &result {
                        assert_routing_legal(&ic, 16, r, packed.nets().len(), &ctx);
                    }
                    if !core.changes_results() {
                        assert_eq!(
                            result.is_ok(),
                            heap.is_ok(),
                            "{ctx}: pure core diverged on routability"
                        );
                        if let (Ok(r), Ok(hr)) = (&result, &heap) {
                            trees_identical(r, hr, &ctx);
                        }
                    }
                }
            }
        }
    }
}

/// Bitstream-level identity for the pure cores on a real app: the
/// encoded text a `bucket` or `radix` route produces is byte-for-byte
/// the binary-heap bitstream. (Tree identity implies this, but the
/// bitstream is the artifact that leaves the toolchain — lock it
/// directly.)
#[test]
fn flags_off_bitstream_is_bit_identical_across_pure_cores() {
    let ic = create_uniform_interconnect(&InterconnectConfig::paper_baseline(8, 8));
    let params = FlowParams {
        sa: SaParams { moves_per_node: 4, ..Default::default() },
        ..Default::default()
    };
    let flow = run_flow(&ic, &canal::apps::gaussian(), &params).expect("baseline flow");
    let cs = allocate(&ic);
    let bitstream_of = |core: SearchCore| -> String {
        let r = route(
            &ic,
            &flow.packed.app,
            &flow.placement,
            16,
            &RouterParams { search_core: core, ..Default::default() },
        )
        .unwrap_or_else(|e| panic!("{}: {e:?}", core.name()));
        let config = Configuration::from_routing(&ic, 16, &r).expect("legal routing encodes");
        encode(&config, &cs).to_text()
    };
    let reference = bitstream_of(SearchCore::BinaryHeap);
    assert!(!reference.is_empty());
    for core in [SearchCore::Bucket, SearchCore::Radix] {
        assert_eq!(
            bitstream_of(core),
            reference,
            "{} bitstream must be bit-identical to binary-heap",
            core.name()
        );
    }
}

/// Engine-level identity: a sweep run with `bucket`/`radix` produces
/// the same `JobKey`s (the descriptor must not fork — pre-variant cache
/// entries keep answering) and f64-bit-identical `PointResult`s as the
/// default run, with the same total `route_expansions`. `astar` forks
/// every key with an ` rcore=astar` token.
#[test]
fn flags_off_engine_points_are_bit_identical_and_share_cache_keys() {
    let spec_with = |core: SearchCore| SweepSpec {
        name: "router-variants".into(),
        base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
        tracks: vec![3, 4],
        apps: vec!["pointwise".into(), "gaussian".into()],
        seeds: vec![1, 2],
        flow: FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            router: RouterParams { search_core: core, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    let run = |core: SearchCore| {
        let mut engine =
            DseEngine::new(EngineOptions { workers: 2, cache_path: None, warm_start: false })
                .expect("engine");
        engine.run(&spec_with(core), &NativePlacer::default()).expect("sweep")
    };

    let default_run = run(SearchCore::BinaryHeap);
    assert_eq!(default_run.points.len(), 8);
    assert!(default_run.stats.route_expansions > 0, "expansion counter is live");
    for (job, _) in &default_run.points {
        for tok in ["rcore=", "rorder=", "rsinks="] {
            assert!(
                !job.key.config.0.contains(tok),
                "default descriptor must carry no variant tokens: {}",
                job.key.config.0
            );
        }
    }

    for core in [SearchCore::Bucket, SearchCore::Radix] {
        let variant = run(core);
        assert_eq!(variant.points.len(), default_run.points.len());
        assert_eq!(
            variant.stats.route_expansions, default_run.stats.route_expansions,
            "{}: pure core changed the search effort",
            core.name()
        );
        for ((ja, ra), (jb, rb)) in default_run.points.iter().zip(&variant.points) {
            assert_eq!(ja.key, jb.key, "{}: cache key forked", core.name());
            assert_eq!(ra, rb, "{} {:?}", core.name(), ja.key);
            assert_eq!(ra.runtime_ns.to_bits(), rb.runtime_ns.to_bits());
            assert_eq!(ra.critical_path_ps.to_bits(), rb.critical_path_ps.to_bits());
        }
    }

    let astar = run(SearchCore::AStar);
    for ((ja, _), (jb, _)) in default_run.points.iter().zip(&astar.points) {
        assert!(
            jb.key.config.0.contains(" rcore=astar"),
            "astar must fork the cache key: {}",
            jb.key.config.0
        );
        assert_ne!(ja.key.config, jb.key.config);
    }
}

/// Slack-ordering golden regression. Ordering is only re-sorted *after*
/// an unresolved iteration, so on fixtures that route congestion-free in
/// one pass the flag must change nothing at all (checked bit-for-bit);
/// across the whole fixture family — sized to include congested points —
/// it must not slow aggregate convergence, and per fixture the critical
/// path stays within the warm-start 5% bar.
#[test]
fn slack_ordering_converges_no_slower_and_preserves_critical_path() {
    let fixtures: &[(&str, u16)] =
        &[("harris", 3), ("harris", 4), ("gaussian", 2), ("gaussian", 3), ("pointwise", 2)];
    let params = FlowParams {
        sa: SaParams { moves_per_node: 4, ..Default::default() },
        ..Default::default()
    };
    let mut routed = 0usize;
    let mut iters_default = 0usize;
    let mut iters_slack = 0usize;
    for &(name, tracks) in fixtures {
        let cfg = InterconnectConfig {
            num_tracks: tracks,
            ..InterconnectConfig::paper_baseline(8, 8)
        };
        let ic = create_uniform_interconnect(&cfg);
        let app = match name {
            "harris" => canal::apps::harris(),
            "gaussian" => canal::apps::gaussian(),
            _ => canal::apps::pointwise(8),
        };
        // One placement per fixture; both orderings route the same one.
        let Ok(flow) = run_flow(&ic, &app, &params) else { continue };
        let base = route(&ic, &flow.packed.app, &flow.placement, 16, &RouterParams::default())
            .expect("default router succeeded inside run_flow");
        let slack = route(
            &ic,
            &flow.packed.app,
            &flow.placement,
            16,
            &RouterParams { slack_order: true, ..Default::default() },
        )
        .unwrap_or_else(|e| panic!("{name}@{tracks}: slack ordering broke a routable fixture: {e:?}"));
        assert_routing_legal(
            &ic,
            16,
            &slack,
            flow.packed.app.nets().len(),
            &format!("{name}@{tracks} slack"),
        );

        if base.iterations == 1 {
            // No negotiation happened, so the re-sort never ran: the
            // flag must be a bit-level no-op here.
            trees_identical(&slack, &base, &format!("{name}@{tracks} uncongested"));
        }
        let cp_base = analyze(&ic, &flow.packed, &base, 16, 256).critical_path_ps;
        let cp_slack = analyze(&ic, &flow.packed, &slack, 16, 256).critical_path_ps;
        assert!(
            cp_slack <= cp_base * 1.05,
            "{name}@{tracks}: slack ordering worsened STA: {cp_slack} vs {cp_base}"
        );
        routed += 1;
        iters_default += base.iterations;
        iters_slack += slack.iterations;
    }
    assert!(routed >= 2, "fixture family collapsed — widen it");
    assert!(
        iters_slack <= iters_default,
        "slack ordering slowed aggregate convergence: {iters_slack} vs {iters_default}"
    );
}
