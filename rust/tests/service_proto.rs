//! Protocol-level tests of the daemon wire format, from the outside
//! (integration view): the CLI↔wire lockstep of sweep parameters, the
//! single-line framing guarantee under hostile content, and exact
//! result reconstruction.

use canal::dse::{
    outcome_json, stats_json, DseEngine, EngineStats, PointResult, SeedMode, Sizing, SweepSpec,
};
use canal::dsl::{InterconnectConfig, OutputTrackMode, SbTopology};
use canal::pnr::{FlowParams, NativePlacer, SaParams};
use canal::service::proto::{parse_request, point_result_from_json, request_line};
use canal::service::{DseParams, Frame, Request};
use canal::sim::FabricKind;
use canal::util::json::Json;

/// The spec a pre-service `canal dse` would have built from
/// `--tracks 3,4 --topologies wilton,disjoint --apps gaussian
///  --seeds 2 --seed 5 --sa-moves 6 --derived-seeds --area`,
/// constructed by hand the way the old CLI code did.
fn hand_built_cli_spec() -> SweepSpec {
    SweepSpec {
        name: "cli".into(),
        base: InterconnectConfig {
            width: 8,
            height: 8,
            mem_column_period: 3,
            ..Default::default()
        },
        tracks: vec![3, 4],
        topologies: vec![SbTopology::Wilton, SbTopology::Disjoint],
        output_tracks: vec![],
        sb_sides: vec![],
        cb_sides: vec![],
        fabrics: vec![],
        sizing: Sizing::Fixed,
        apps: vec!["gaussian".into()],
        seeds: vec![5, 6],
        seed_mode: SeedMode::Derived,
        flow: FlowParams {
            sa: SaParams { moves_per_node: 6, ..Default::default() },
            ..Default::default()
        },
        area: true,
    }
}

fn equivalent_params() -> DseParams {
    DseParams {
        tracks: vec![3, 4],
        topologies: vec![SbTopology::Wilton, SbTopology::Disjoint],
        apps: vec!["gaussian".into()],
        seed: 5,
        seeds: 2,
        derived_seeds: true,
        sa_moves: 6,
        area: true,
        ..Default::default()
    }
}

#[test]
fn wire_params_build_the_same_jobs_as_the_cli_spec() {
    let direct = hand_built_cli_spec().jobs("native-gd").unwrap();
    let via_params = equivalent_params().to_spec().jobs("native-gd").unwrap();
    assert_eq!(direct.len(), via_params.len());
    for (a, b) in direct.iter().zip(&via_params) {
        assert_eq!(a.key, b.key, "CLI and wire construction must agree on job keys");
        assert_eq!(a.flow.seed, b.flow.seed, "derived seed streams must agree");
        assert_eq!(a.fabric, b.fabric);
    }
}

#[test]
fn params_survive_the_wire_with_jobs_intact() {
    // params → request line → parsed request → to_spec must preserve
    // the exact job list (the daemon sees what the client meant).
    let p = DseParams {
        fabrics: vec![FabricKind::Static, FabricKind::RvFullFifo { depth: 3 }],
        out_tracks: vec![OutputTrackMode::AllTracks, OutputTrackMode::Pinned],
        sb_sides: vec![4, 3],
        tight: Some(1.25),
        ..equivalent_params()
    };
    let line = request_line(9, &Request::Dse(p.clone()));
    let (id, parsed) = parse_request(&line).unwrap();
    assert_eq!(id, 9);
    let Request::Dse(back) = parsed else { panic!("wrong request kind") };
    assert_eq!(back, p);
    let a = p.to_spec().jobs("native-gd").unwrap();
    let b = back.to_spec().jobs("native-gd").unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.key, y.key);
    }
}

#[test]
fn frames_survive_hostile_table_content() {
    // Rendered tables are full of newlines and box-drawing characters;
    // error strings can contain anything a config descriptor can. None
    // of it may break the one-line framing.
    let hostile_table = "Fig. X — results\n| a | b |\n|---|---|\n| 1 | \"q\\u{7}\" |\n";
    let frames = [
        Frame::Result {
            id: 1,
            data: Json::Obj(vec![("table".into(), Json::str(hostile_table))]),
        },
        Frame::Error { id: 2, error: "descriptor `8x8 t=5\nfabric=rv-full:2`".into() },
        Frame::Progress { id: 3, message: "phase\r\ndone".into() },
    ];
    for f in &frames {
        let line = f.to_line();
        assert!(
            !line.bytes().any(|b| b == b'\n' || b == b'\r'),
            "frame embeds a newline: {line:?}"
        );
        assert_eq!(&Frame::parse(&line).unwrap(), f);
    }
    // And a full NDJSON exchange splits back into exactly 3 frames.
    let stream: String = frames.iter().map(|f| f.to_line() + "\n").collect();
    let parsed: Vec<Frame> =
        stream.lines().map(|l| Frame::parse(l).unwrap()).collect();
    assert_eq!(parsed.len(), 3);
    assert_eq!(&parsed[..], &frames[..]);
}

#[test]
fn unroutable_and_nan_points_reconstruct_exactly() {
    // An unroutable cached point (all-zero metrics) and a NaN metric
    // (written as null) must both survive the wire.
    let spec = SweepSpec {
        base: InterconnectConfig { mem_column_period: 3, ..Default::default() },
        apps: vec!["pointwise".into()],
        flow: FlowParams {
            sa: SaParams { moves_per_node: 4, ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };
    let mut engine = DseEngine::in_memory();
    let mut out = engine.run(&spec, &NativePlacer::default()).unwrap();
    out.points[0].1 = PointResult::unroutable();
    let doc = Json::parse(&outcome_json(&out).render_line()).unwrap();
    let wire = &doc.get("points").and_then(Json::as_arr).unwrap()[0];
    let back = point_result_from_json(wire).unwrap();
    assert_eq!(back, PointResult::unroutable());

    let mut nan_point = PointResult::unroutable();
    nan_point.routed = true;
    nan_point.runtime_ns = f64::NAN;
    out.points[0].1 = nan_point;
    let doc = Json::parse(&outcome_json(&out).render_line()).unwrap();
    let wire = &doc.get("points").and_then(Json::as_arr).unwrap()[0];
    let back = point_result_from_json(wire).unwrap();
    assert!(back.runtime_ns.is_nan(), "null metric must come back as NaN");
}

#[test]
fn engine_stats_serialize_with_the_coalesced_counter() {
    let s = EngineStats {
        jobs: 10,
        cache_hits: 4,
        coalesced: 3,
        pnr_runs: 3,
        sims: 3,
        ..Default::default()
    };
    let j = stats_json(&s);
    assert_eq!(j.get("jobs").and_then(Json::as_u64), Some(10));
    assert_eq!(j.get("coalesced").and_then(Json::as_u64), Some(3));
    assert_eq!(j.get("pnr_runs").and_then(Json::as_u64), Some(3));
    // Single-line by construction — frames embed this object.
    assert!(!j.render_line().contains('\n'));
}
