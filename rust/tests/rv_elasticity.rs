//! End-to-end property tests for the elastic (ready-valid) simulator's
//! two documented invariants (`sim/rv_sim.rs` module docs):
//!
//! 1. **Elasticity preserves values** — on randomized app graphs, with
//!    randomized per-edge channel capacities ("random routes": capacity
//!    varies per edge the way registers-crossed varies per routed net),
//!    *any* stall pattern yields exactly the output sequence of the
//!    unconstrained run.
//! 2. **Deeper FIFOs never reduce throughput** — for the same graph and
//!    workload, increasing every channel's capacity never increases the
//!    cycle count (and the output sequences stay identical).
//!
//! A third test grounds both invariants on *real* routes: capacities
//! derived from an actual PnR result via `routed_capacities`.

use std::collections::HashMap;

use canal::pnr::{AppGraph, AppNodeId, AppOp};
use canal::sim::{routed_capacities, FabricKind, RvSim, StallPattern};
use canal::util::rng::Rng;

type Caps = HashMap<(AppNodeId, u8, AppNodeId, u8), usize>;

fn uniform_caps(app: &AppGraph, cap: usize) -> Caps {
    app.edges().iter().map(|e| ((e.src, e.src_port, e.dst, e.dst_port), cap)).collect()
}

fn random_caps(app: &AppGraph, rng: &mut Rng, max_extra: usize) -> Caps {
    app.edges()
        .iter()
        .map(|e| ((e.src, e.src_port, e.dst, e.dst_port), 1 + rng.below(max_extra + 1)))
        .collect()
}

fn stream(n: usize) -> Vec<i64> {
    (0..n as i64).map(|i| (i * 11 + 5) % 241).collect()
}

/// Random layered feed-forward dataflow graph. Construction guarantees
/// the properties the simulator's completion depends on: every vertex
/// feeds forward into the next layer (no dead ends that would absorb
/// backpressure forever), every compute vertex has at least one input,
/// and the final survivor drains into a stream sink. Includes the whole
/// op/vertex menagerie: binary and unary ALUs, `mac` accumulators,
/// explicit `Reg` delay vertices, linebuffers, and packed-style consts.
fn random_app(seed: u64) -> AppGraph {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ 0xE1A5_71C0);
    let mut g = AppGraph::new(&format!("rand{seed}"));
    let mut uid = 0usize;
    let fresh = |prefix: &str, uid: &mut usize| {
        *uid += 1;
        format!("{prefix}{uid}")
    };

    let n_inputs = 1 + rng.below(2);
    let mut pool: Vec<AppNodeId> =
        (0..n_inputs).map(|i| g.mem(&format!("in{i}"), "stream_in")).collect();
    // Occasionally widen the frontier so reconvergence happens.
    if rng.below(2) == 0 {
        let lb = g.mem(&fresh("lb", &mut uid), "linebuffer");
        g.wire(pool[0], lb, 0);
        pool.push(lb);
    }

    let binary_ops = ["add", "sub", "mul", "max", "min", "ashr"];
    let unary_ops = ["abs", "mac"];
    let mut layers = 2 + rng.below(3);
    while pool.len() > 1 || layers > 0 {
        layers = layers.saturating_sub(1);
        let mut next = Vec::new();
        let mut i = 0;
        while i < pool.len() {
            // Maybe delay the left operand through an explicit register.
            let mut a = pool[i];
            if rng.below(4) == 0 {
                let r = g.add(&fresh("r", &mut uid), AppOp::Reg);
                g.wire(a, r, 0);
                a = r;
            }
            if i + 1 < pool.len() {
                // Pair-reduce two frontier nodes through a binary ALU.
                let b = pool[i + 1];
                let op = binary_ops[rng.below(binary_ops.len())];
                let v = g.alu(&fresh("v", &mut uid), op);
                g.wire(a, v, 0);
                g.wire(b, v, 1);
                next.push(v);
                i += 2;
            } else {
                // Odd node out: unary ALU, or binary against a constant.
                if rng.below(2) == 0 {
                    let op = unary_ops[rng.below(unary_ops.len())];
                    let v = g.alu(&fresh("u", &mut uid), op);
                    g.wire(a, v, 0);
                    next.push(v);
                } else {
                    let k = g.add(
                        &fresh("k", &mut uid),
                        AppOp::Const(1 + rng.below(7) as i64),
                    );
                    let op = binary_ops[rng.below(binary_ops.len())];
                    let v = g.alu(&fresh("c", &mut uid), op);
                    g.wire(a, v, 0);
                    g.wire(k, v, 1);
                    next.push(v);
                }
                i += 1;
            }
        }
        pool = next;
        if pool.len() == 1 && layers == 0 {
            break;
        }
    }
    let out = g.mem("out", "stream_out");
    g.wire(pool[0], out, 0);
    g.check().unwrap_or_else(|e| panic!("random_app({seed}) malformed: {e}"));
    g
}

fn stall_patterns(seed: u64) -> Vec<StallPattern> {
    vec![
        StallPattern::Bursty { accept: 1, stall: 1 },
        StallPattern::Bursty { accept: 3, stall: 2 },
        StallPattern::Bursty { accept: 2, stall: 5 },
        StallPattern::Random { p: 0.2, seed: seed ^ 0xA5 },
        StallPattern::Random { p: 0.5, seed: seed ^ 0x5A },
    ]
}

#[test]
fn any_stall_pattern_yields_the_unconstrained_sequence() {
    // Invariant 1 on random graphs × random capacities × stall families.
    let n = 20;
    for seed in 0..10u64 {
        let g = random_app(seed);
        let mut rng = Rng::new(seed ^ 0xCAB5);
        let caps = random_caps(&g, &mut rng, 3);
        let free = RvSim::new(&g, &caps, stream(256)).run(n, 500_000, StallPattern::None);
        assert_eq!(free.tokens, n, "seed {seed}: unconstrained run incomplete");
        for stall in stall_patterns(seed) {
            let run = RvSim::new(&g, &caps, stream(256)).run(n, 500_000, stall);
            assert_eq!(run.tokens, n, "seed {seed} {stall:?}: stalled run incomplete");
            for (name, seq) in &free.outputs {
                assert_eq!(
                    &run.outputs[name], seq,
                    "seed {seed} {stall:?}: {name} sequence diverged"
                );
            }
        }
    }
}

#[test]
fn deeper_fifos_never_reduce_throughput() {
    // Invariant 2: same graph, same workload, uniformly deeper channels
    // ⇒ cycle count is non-increasing, values unchanged. Checked both
    // free-running and under bursty backpressure.
    let n = 24;
    for seed in 0..10u64 {
        let g = random_app(seed);
        for stall in [StallPattern::None, StallPattern::Bursty { accept: 2, stall: 3 }] {
            let mut prev_cycles = usize::MAX;
            let mut prev_out = None;
            for cap in [1usize, 2, 3, 6] {
                let run = RvSim::new(&g, &uniform_caps(&g, cap), stream(256))
                    .run(n, 500_000, stall);
                assert_eq!(run.tokens, n, "seed {seed} cap {cap} {stall:?} incomplete");
                assert!(
                    run.cycles <= prev_cycles,
                    "seed {seed} {stall:?}: cap {cap} took {} cycles, shallower took {}",
                    run.cycles,
                    prev_cycles
                );
                prev_cycles = run.cycles;
                if let Some(prev) = &prev_out {
                    assert_eq!(prev, &run.outputs, "seed {seed} cap {cap}: values changed");
                }
                prev_out = Some(run.outputs);
            }
        }
    }
}

#[test]
fn fabric_capacity_models_are_ordered() {
    // The three DSE fabric kinds on the same (randomized) register
    // counts: rv-full(2) ⊇ rv-split ⊇ static capacity-wise, so cycle
    // counts must order the opposite way.
    let n = 24;
    for seed in 0..6u64 {
        let g = random_app(seed);
        let mut rng = Rng::new(seed ^ 0xF00D);
        let regs: Vec<usize> = g.edges().iter().map(|_| rng.below(3)).collect();
        let caps_for = |fabric: FabricKind| -> Caps {
            g.edges()
                .iter()
                .zip(&regs)
                .map(|(e, &r)| ((e.src, e.src_port, e.dst, e.dst_port), fabric.capacity(r)))
                .collect()
        };
        let run = |fabric: FabricKind| {
            RvSim::new(&g, &caps_for(fabric), stream(256)).run(n, 500_000, StallPattern::None)
        };
        let stat = run(FabricKind::Static);
        let split = run(FabricKind::RvSplitFifo);
        let full = run(FabricKind::RvFullFifo { depth: 2 });
        assert_eq!(stat.tokens, n, "seed {seed}");
        assert!(split.cycles <= stat.cycles, "seed {seed}: split slower than static");
        assert!(full.cycles <= split.cycles, "seed {seed}: full slower than split");
        assert_eq!(stat.outputs, split.outputs, "seed {seed}");
        assert_eq!(stat.outputs, full.outputs, "seed {seed}");
    }
}

#[test]
fn routed_fabrics_preserve_sequences_and_elasticity() {
    // Ground the invariants on a real PnR result: capacities derived
    // from the registers each routed net actually crosses.
    use canal::dsl::{create_uniform_interconnect, InterconnectConfig};
    use canal::pnr::{run_flow, FlowParams, SaParams};
    let ic = create_uniform_interconnect(&InterconnectConfig {
        width: 8,
        height: 8,
        num_tracks: 5,
        mem_column_period: 3,
        ..Default::default()
    });
    let app = canal::apps::gaussian();
    let params = FlowParams {
        sa: SaParams { moves_per_node: 6, ..Default::default() },
        ..Default::default()
    };
    let flow = run_flow(&ic, &app, &params).expect("gaussian routes");
    let n = 32;
    let caps_for = |fabric: FabricKind| {
        routed_capacities(&app, &flow.packed, &ic, 16, &flow.routing, fabric)
    };
    let stat =
        RvSim::new(&app, &caps_for(FabricKind::Static), stream(256)).run(n, 500_000, StallPattern::None);
    assert_eq!(stat.tokens, n);
    for fabric in [FabricKind::RvFullFifo { depth: 2 }, FabricKind::RvSplitFifo] {
        let caps = caps_for(fabric);
        let free = RvSim::new(&app, &caps, stream(256)).run(n, 500_000, StallPattern::None);
        assert!(free.cycles <= stat.cycles, "{fabric:?} slower than static");
        assert_eq!(free.outputs, stat.outputs, "{fabric:?} changed values");
        for stall in stall_patterns(7) {
            let run = RvSim::new(&app, &caps, stream(256)).run(n, 500_000, stall);
            assert_eq!(run.tokens, n, "{fabric:?} {stall:?} incomplete");
            assert_eq!(run.outputs, free.outputs, "{fabric:?} {stall:?} diverged");
        }
    }
}
