//! Property test for incremental PnR (`run_flow_warm`): over randomized
//! neighbor pairs of interconnect configurations — tracks ±1, or one
//! connected side toggled — a point warm-started from its neighbor's
//! artifacts must always produce a *legal* result: placement passes
//! `Placement::check`, routing passes the shared legality suite
//! (`common::route_check`), and the reuse counters account for every
//! net exactly once. A second test locks down the Steiner-artifact
//! replay contract: multi-fanout trees round-trip through the
//! `PnrArtifactCache` token encoding and replay verbatim.
//!
//! The pair generator is a fixed-seed LCG, so the "random" pairs are
//! reproducible; no external proptest crate is involved.

mod common;

use canal::apps;
use canal::dse::{encode_node, JobKey, PnrArtifact, PnrArtifactCache};
use canal::dse::{ConfigDescriptor, SeedMode};
use canal::dsl::{create_uniform_interconnect, ConnectedSides, InterconnectConfig};
use canal::ir::Interconnect;
use canal::pnr::{run_flow, run_flow_warm, FlowParams, FlowResult, RouterScratch, SaParams, WarmSeed};
use canal::sim::FabricKind;

use common::route_check::assert_routing_legal;

/// Deterministic 64-bit LCG (Knuth's MMIX constants); top bits only.
fn next(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// Snapshot a finished flow the way the DSE executor does: legalized
/// placement plus routed sink paths as logical node tokens.
fn artifact_of(ic: &Interconnect, flow: &FlowResult) -> PnrArtifact {
    let rg = ic.graph(16);
    PnrArtifact {
        placement: flow.placement.pos.clone(),
        nets: flow
            .routing
            .trees
            .iter()
            .map(|t| {
                t.sink_paths
                    .iter()
                    .map(|p| p.iter().map(|&n| encode_node(rg, n)).collect())
                    .collect()
            })
            .collect(),
    }
}

/// One axis mutation: tracks ±1 (floored at 2) or one connected-side
/// toggle (4 ↔ 3) — exactly the neighborhoods the sweep executor
/// warm-starts across.
fn neighbor_of(base: &InterconnectConfig, pick: u64) -> InterconnectConfig {
    let mut cfg = base.clone();
    match pick % 4 {
        0 => cfg.num_tracks += 1,
        1 => cfg.num_tracks = (cfg.num_tracks - 1).max(2),
        2 => {
            cfg.sb_core_sides =
                if cfg.sb_core_sides.0 == 4 { ConnectedSides::THREE } else { ConnectedSides::FOUR }
        }
        _ => {
            cfg.cb_core_sides =
                if cfg.cb_core_sides.0 == 4 { ConnectedSides::THREE } else { ConnectedSides::FOUR }
        }
    }
    cfg
}

#[test]
fn random_neighbor_pairs_warm_start_to_legal_disjoint_routing() {
    let params = FlowParams {
        sa: SaParams { moves_per_node: 4, ..Default::default() },
        ..Default::default()
    };
    let mut rng = 0xC0FFEEu64;
    let mut scratch = RouterScratch::new();
    for trial in 0..6 {
        let app = if next(&mut rng) % 2 == 0 { apps::pointwise(6) } else { apps::gaussian() };
        let donor_cfg = InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3 + (next(&mut rng) % 2) as u16,
            mem_column_period: 3,
            ..Default::default()
        };
        let target_cfg = neighbor_of(&donor_cfg, next(&mut rng));
        let donor_ic = create_uniform_interconnect(&donor_cfg);
        let target_ic = create_uniform_interconnect(&target_cfg);

        // Scratch flow on the donor config supplies the artifacts.
        let donor_flow = run_flow(&donor_ic, &app, &params)
            .unwrap_or_else(|e| panic!("trial {trial}: donor flow failed: {e:?}"));
        let art = artifact_of(&donor_ic, &donor_flow);

        // Warm-start the neighbor from them.
        let net_paths = art.resolve(target_ic.graph(16));
        let seed = WarmSeed { placement: &art.placement, net_paths };
        let (flow, reuse) = run_flow_warm(&target_ic, &app, &params, &seed, &mut scratch)
            .unwrap_or_else(|e| {
                panic!(
                    "trial {trial}: warm flow failed ({} -> {}): {e:?}",
                    donor_ic.descriptor, target_ic.descriptor
                )
            });

        // Legal placement on the TARGET fabric.
        flow.placement
            .check(&flow.packed.app, &target_ic)
            .unwrap_or_else(|e| panic!("trial {trial}: illegal warm placement: {e}"));

        // Reuse counters account for each net exactly once.
        assert_eq!(
            reuse.nets_reused + reuse.nets_rerouted,
            flow.routing.trees.len(),
            "trial {trial}: every net is either reused or rerouted"
        );

        // Full shared legality suite against the TARGET graph (the donor
        // trees came from a *different* graph — replay must never smuggle
        // in an edge the target fabric doesn't have).
        assert_routing_legal(
            &target_ic,
            16,
            &flow.routing,
            flow.packed.app.nets().len(),
            &format!(
                "trial {trial} ({} -> {})",
                donor_ic.descriptor, target_ic.descriptor
            ),
        );
    }
}

/// The Steiner-artifact replay contract: a multi-fanout flow's routed
/// trees survive the `PnrArtifactCache` round-trip (struct → JSON text →
/// struct → token resolution) byte-for-byte, and warm-starting the SAME
/// configuration from them replays every tree verbatim — zero router
/// iterations, zero search expansions, `nets_reused == nets`. Corrupting
/// one net's seed flips exactly that net into `nets_rerouted` while the
/// result stays legal.
#[test]
fn steiner_artifacts_roundtrip_and_replay_verbatim() {
    let params = FlowParams {
        sa: SaParams { moves_per_node: 4, ..Default::default() },
        ..Default::default()
    };
    let cfg = InterconnectConfig {
        width: 6,
        height: 6,
        num_tracks: 4,
        mem_column_period: 3,
        ..Default::default()
    };
    let ic = create_uniform_interconnect(&cfg);
    let app = apps::gaussian();
    let mut scratch = RouterScratch::new();

    let flow = run_flow(&ic, &app, &params).expect("cold flow");
    let fanout = flow
        .routing
        .trees
        .iter()
        .filter(|t| t.net.sinks.len() > 1)
        .count();
    assert!(fanout > 0, "fixture must exercise multi-fanout Steiner trees");
    let art = artifact_of(&ic, &flow);

    // Round-trip through the artifact cache's JSON encoding, exactly as
    // a persisted sweep would.
    let key = JobKey {
        config: ConfigDescriptor::of(&cfg, &params, "native-gd", SeedMode::Raw, FabricKind::Static),
        app: "gaussian".into(),
        seed: 1,
    };
    let store = PnrArtifactCache::in_memory();
    store.insert(key.clone(), art.clone());
    let reloaded = PnrArtifactCache::in_memory();
    reloaded.load_json(&store.to_json()).expect("artifact JSON round-trip");
    let back = reloaded.get(&key).expect("entry survives the round-trip");
    assert_eq!(*back, art, "token encoding must be lossless");

    // Verbatim replay on the same fabric: every tree reused, the router
    // never iterates, the search cores never pop a node.
    let net_paths = back.resolve(ic.graph(16));
    assert!(
        net_paths.iter().all(Option::is_some),
        "every token resolves on the graph it came from"
    );
    let seed = WarmSeed { placement: &back.placement, net_paths };
    let (warm, reuse) =
        run_flow_warm(&ic, &app, &params, &seed, &mut scratch).expect("warm flow");
    assert_eq!(reuse.nets_reused, warm.routing.trees.len(), "all nets replay");
    assert_eq!(reuse.nets_rerouted, 0);
    assert_eq!(warm.routing.iterations, 0, "verbatim replay skips PathFinder");
    assert_eq!(warm.routing.route_expansions, 0, "verbatim replay searches nothing");
    for (a, b) in warm.routing.trees.iter().zip(&flow.routing.trees) {
        assert_eq!(a.sink_paths, b.sink_paths, "replayed tree differs from donor");
    }
    assert_routing_legal(&ic, 16, &warm.routing, warm.packed.app.nets().len(), "replay");

    // Corrupt the largest multi-fanout net's seed: that net (and only
    // that net) must fall into the rerouted bucket, and the result must
    // still pass the full legality suite.
    let (victim, _) = flow
        .routing
        .trees
        .iter()
        .enumerate()
        .max_by_key(|(_, t)| t.net.sinks.len())
        .expect("at least one net");
    let mut net_paths = back.resolve(ic.graph(16));
    net_paths[victim] = None;
    let seed = WarmSeed { placement: &back.placement, net_paths };
    let (warm, reuse) =
        run_flow_warm(&ic, &app, &params, &seed, &mut scratch).expect("warm flow after corruption");
    assert_eq!(
        reuse.nets_reused + reuse.nets_rerouted,
        warm.routing.trees.len(),
        "accounting stays exact under corruption"
    );
    assert!(reuse.nets_rerouted >= 1, "the voided net was rerouted");
    assert!(reuse.nets_reused > 0, "intact seeds still replay");
    assert!(warm.routing.route_expansions > 0, "rerouting the victim costs expansions");
    assert_routing_legal(&ic, 16, &warm.routing, warm.packed.app.nets().len(), "corrupted seed");
}
