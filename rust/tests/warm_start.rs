//! Property test for incremental PnR (`run_flow_warm`): over randomized
//! neighbor pairs of interconnect configurations — tracks ±1, or one
//! connected side toggled — a point warm-started from its neighbor's
//! artifacts must always produce a *legal* result: placement passes
//! `Placement::check`, every net routes, routed trees are node-disjoint,
//! and the reuse counters account for every net exactly once.
//!
//! The pair generator is a fixed-seed LCG, so the "random" pairs are
//! reproducible; no external proptest crate is involved.

use std::collections::HashMap;

use canal::apps;
use canal::dse::{encode_node, PnrArtifact};
use canal::dsl::{create_uniform_interconnect, ConnectedSides, InterconnectConfig};
use canal::ir::{Interconnect, NodeId};
use canal::pnr::{run_flow, run_flow_warm, FlowParams, FlowResult, RouterScratch, SaParams, WarmSeed};

/// Deterministic 64-bit LCG (Knuth's MMIX constants); top bits only.
fn next(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// Snapshot a finished flow the way the DSE executor does: legalized
/// placement plus routed sink paths as logical node tokens.
fn artifact_of(ic: &Interconnect, flow: &FlowResult) -> PnrArtifact {
    let rg = ic.graph(16);
    PnrArtifact {
        placement: flow.placement.pos.clone(),
        nets: flow
            .routing
            .trees
            .iter()
            .map(|t| {
                t.sink_paths
                    .iter()
                    .map(|p| p.iter().map(|&n| encode_node(rg, n)).collect())
                    .collect()
            })
            .collect(),
    }
}

/// One axis mutation: tracks ±1 (floored at 2) or one connected-side
/// toggle (4 ↔ 3) — exactly the neighborhoods the sweep executor
/// warm-starts across.
fn neighbor_of(base: &InterconnectConfig, pick: u64) -> InterconnectConfig {
    let mut cfg = base.clone();
    match pick % 4 {
        0 => cfg.num_tracks += 1,
        1 => cfg.num_tracks = (cfg.num_tracks - 1).max(2),
        2 => {
            cfg.sb_core_sides =
                if cfg.sb_core_sides.0 == 4 { ConnectedSides::THREE } else { ConnectedSides::FOUR }
        }
        _ => {
            cfg.cb_core_sides =
                if cfg.cb_core_sides.0 == 4 { ConnectedSides::THREE } else { ConnectedSides::FOUR }
        }
    }
    cfg
}

#[test]
fn random_neighbor_pairs_warm_start_to_legal_disjoint_routing() {
    let params = FlowParams {
        sa: SaParams { moves_per_node: 4, ..Default::default() },
        ..Default::default()
    };
    let mut rng = 0xC0FFEEu64;
    let mut scratch = RouterScratch::new();
    for trial in 0..6 {
        let app = if next(&mut rng) % 2 == 0 { apps::pointwise(6) } else { apps::gaussian() };
        let donor_cfg = InterconnectConfig {
            width: 6,
            height: 6,
            num_tracks: 3 + (next(&mut rng) % 2) as u16,
            mem_column_period: 3,
            ..Default::default()
        };
        let target_cfg = neighbor_of(&donor_cfg, next(&mut rng));
        let donor_ic = create_uniform_interconnect(&donor_cfg);
        let target_ic = create_uniform_interconnect(&target_cfg);

        // Scratch flow on the donor config supplies the artifacts.
        let donor_flow = run_flow(&donor_ic, &app, &params)
            .unwrap_or_else(|e| panic!("trial {trial}: donor flow failed: {e:?}"));
        let art = artifact_of(&donor_ic, &donor_flow);

        // Warm-start the neighbor from them.
        let net_paths = art.resolve(target_ic.graph(16));
        let seed = WarmSeed { placement: &art.placement, net_paths };
        let (flow, reuse) = run_flow_warm(&target_ic, &app, &params, &seed, &mut scratch)
            .unwrap_or_else(|e| {
                panic!(
                    "trial {trial}: warm flow failed ({} -> {}): {e:?}",
                    donor_ic.descriptor, target_ic.descriptor
                )
            });

        // Legal placement on the TARGET fabric.
        flow.placement
            .check(&flow.packed.app, &target_ic)
            .unwrap_or_else(|e| panic!("trial {trial}: illegal warm placement: {e}"));

        // Every net routed; reuse counters account for each exactly once.
        assert_eq!(flow.routing.trees.len(), flow.packed.app.nets().len(), "trial {trial}");
        assert_eq!(
            reuse.nets_reused + reuse.nets_rerouted,
            flow.routing.trees.len(),
            "trial {trial}: every net is either reused or rerouted"
        );

        // Node-disjoint routing: no routing-graph node serves two nets.
        let mut owner: HashMap<NodeId, usize> = HashMap::new();
        for (ni, tree) in flow.routing.trees.iter().enumerate() {
            assert!(!tree.sink_paths.is_empty(), "trial {trial}: net {ni} has no paths");
            for n in tree.nodes() {
                match owner.get(&n) {
                    Some(&other) => panic!(
                        "trial {trial}: node {n:?} shared by nets {other} and {ni} \
                         ({} -> {})",
                        donor_ic.descriptor, target_ic.descriptor
                    ),
                    None => {
                        owner.insert(n, ni);
                    }
                }
            }
        }

        // Every path's edges must exist in the target graph (the donor
        // trees came from a *different* graph — replay must never smuggle
        // in an edge the target fabric doesn't have).
        let g = target_ic.graph(16);
        for tree in &flow.routing.trees {
            for path in &tree.sink_paths {
                for w in path.windows(2) {
                    assert!(
                        g.fan_out(w[0]).contains(&w[1]),
                        "trial {trial}: edge {:?} -> {:?} absent from target graph",
                        w[0],
                        w[1]
                    );
                }
            }
        }
    }
}
